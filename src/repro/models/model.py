"""Unified model definition: every assigned architecture is a list of
*segments* — runs of identical layer "units" executed with ``lax.scan`` over
stacked unit params (keeps HLO small at 40-78 layers and gives the remat
boundary). Heterogeneous stacks (gemma3 5:1 local/global, zamba2 shared
block, xlstm mLSTM/sLSTM, deepseek first-dense) become short segment lists
via run-length encoding of the per-layer spec.

Public API:
    init_params(cfg, rng)                -> params pytree
    loss_fn(params, cfg, batch, rng)     -> (loss, aux)
    init_decode_state(cfg, batch, s_max) -> decode cache pytree
    decode_step(params, cfg, state, tokens) -> (logits, new_state)
    count_params(cfg) / count_active_params(cfg)  (via eval_shape, no alloc)
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models.layers import (apply_norm, apply_swiglu, dense_init,
                                 embed_init, init_norm, init_swiglu, split)


# ---------------------------------------------------------------------------
# segment protocol
# ---------------------------------------------------------------------------

@dataclass
class Segment:
    kind: str
    n: int
    init_unit: Callable          # key -> unit params
    apply_unit: Callable         # (p, x, ctx) -> (x, aux_scalar)
    init_cache: Callable         # (batch, s_max, dtype) -> unit cache (or None)
    decode_unit: Callable        # (p, x1, cache, index, ctx) -> (x1, cache)


def _rle(specs: List[str]) -> List[Tuple[str, int]]:
    out: List[Tuple[str, int]] = []
    for s in specs:
        if out and out[-1][0] == s:
            out[-1] = (s, out[-1][1] + 1)
        else:
            out.append((s, 1))
    return out


# ---------------------------------------------------------------------------
# dense / gqa / mla layer units
# ---------------------------------------------------------------------------

def apply_ffn_unit(p, x, cfg: ModelConfig, *, use_moe: bool = False):
    """FFN half of a transformer unit: ln2 + MLP/MoE dispatch (handles the
    layernorm/gelu family, swiglu, ln2-less and mlp-less variants). Shared
    by the train/decode units here and the paged serve engine
    (repro.serve.engine), which must stay bitwise-identical to this path.
    Returns (ffn_out, aux_scalar)."""
    if use_moe:
        h = apply_norm(p["ln2"], x, cfg.norm)
        return moe_lib.apply_moe(p["moe"], h, cfg)
    if "mlp" not in p:
        return jnp.zeros_like(x), 0.0
    h = apply_norm(p["ln2"], x, cfg.norm) if "ln2" in p else x
    if "w_gate" in p["mlp"]:
        return apply_swiglu(p["mlp"], h), 0.0
    from repro.models.layers import apply_gelu_mlp
    return apply_gelu_mlp(p["mlp"], h), 0.0


def _mk_attn_layer(cfg: ModelConfig, *, window: int, cross: bool = False,
                   causal: bool = True, use_moe: bool = False,
                   dense_ffn: bool = True, shared_after: bool = False,
                   kind: str = "dense"):
    """Builds a Segment unit for one transformer layer."""
    d, dt = cfg.d_model, jnp.dtype(cfg.param_dtype)
    hd = cfg.resolved_head_dim
    is_mla = cfg.attn_type == "mla"

    def init_unit(key):
        ks = split(key, 8)
        p: Dict[str, Any] = {"ln1": init_norm(cfg.norm, d, dt)}
        if is_mla:
            p["attn"] = attn.init_mla(ks[0], d, cfg.n_heads, cfg.mla, dt)
        else:
            p["attn"] = attn.init_gqa(ks[0], d, cfg.n_heads, cfg.n_kv_heads,
                                      hd, dt)
        if cross:
            p["ln_x"] = init_norm(cfg.norm, d, dt)
            p["cross"] = attn.init_gqa(ks[1], d, cfg.n_heads, cfg.n_kv_heads,
                                       hd, dt)
        if use_moe or (dense_ffn and cfg.d_ff > 0):
            p["ln2"] = init_norm(cfg.norm, d, dt)
        if use_moe:
            p["moe"] = moe_lib.init_moe(ks[2], cfg, dt)
        elif dense_ffn and cfg.d_ff > 0:
            if cfg.norm == "layernorm" and cfg.family in ("dense", "audio"):
                from repro.models.layers import init_gelu_mlp
                p["mlp"] = init_gelu_mlp(ks[3], d, cfg.d_ff, dt)
            else:
                p["mlp"] = init_swiglu(ks[3], d, cfg.d_ff, dt)
        return p

    def _self_attn(p, x, ctx):
        h = apply_norm(p["ln1"], x, cfg.norm)
        if is_mla:
            return attn.apply_mla(
                p["attn"], h, ctx["positions"], n_heads=cfg.n_heads,
                mla=cfg.mla, rope_theta=cfg.rope_theta, chunk=ctx["chunk"])
        return attn.apply_gqa(
            p["attn"], h, ctx["positions"], n_heads=cfg.n_heads,
            n_kv=cfg.n_kv_heads, head_dim=hd, rope_theta=cfg.rope_theta,
            causal=causal, window=window, chunk=ctx["chunk"],
            mrope_positions=ctx.get("mrope_positions"),
            mrope_sections=cfg.mrope_sections if cfg.mrope else None)

    def _ffn(p, x, ctx):
        return apply_ffn_unit(p, x, cfg, use_moe=use_moe)

    def apply_unit(p, x, ctx):
        if cfg.parallel_residual and not use_moe:
            a = _self_attn(p, x, ctx)
            f, aux = _ffn(p, x, ctx)
            x = x + a + f
        else:
            x = x + _self_attn(p, x, ctx)
            if cross:
                h = apply_norm(p["ln_x"], x, cfg.norm)
                x = x + attn.apply_cross(p["cross"], h, ctx["enc_memory"],
                                         n_heads=cfg.n_heads,
                                         n_kv=cfg.n_kv_heads, head_dim=hd)
            f, aux = _ffn(p, x, ctx)
            x = x + f
        if shared_after:
            x = _apply_shared_block(ctx["shared_params"], x, ctx, cfg)
        return x, aux

    def init_cache(batch, s_max, dtype):
        if is_mla:
            c = {"self": attn.init_mla_cache(batch, s_max, cfg.mla, dtype)}
        else:
            c = {"self": attn.init_gqa_cache(batch, s_max, cfg.n_kv_heads, hd,
                                             window=window, dtype=dtype)}
        if cross:
            c["cross"] = {"k": jnp.zeros((batch, ctx_enc_len(cfg), cfg.n_kv_heads, hd), dtype),
                          "v": jnp.zeros((batch, ctx_enc_len(cfg), cfg.n_kv_heads, hd), dtype)}
        return c

    def decode_unit(p, x1, cache, index, ctx):
        h = apply_norm(p["ln1"], x1, cfg.norm)
        if is_mla:
            a, new_self = attn.decode_mla(p["attn"], h, cache["self"], index,
                                          n_heads=cfg.n_heads, mla=cfg.mla,
                                          rope_theta=cfg.rope_theta)
        elif "pos" in cache["self"]:
            a, new_self = attn.decode_gqa_ring(
                p["attn"], h, cache["self"], index, n_heads=cfg.n_heads,
                n_kv=cfg.n_kv_heads, head_dim=hd, rope_theta=cfg.rope_theta)
        else:
            a, new_self = attn.decode_gqa(
                p["attn"], h, cache["self"], index, n_heads=cfg.n_heads,
                n_kv=cfg.n_kv_heads, head_dim=hd, rope_theta=cfg.rope_theta,
                window=window,
                mrope_positions=ctx.get("mrope_positions"),
                mrope_sections=cfg.mrope_sections if cfg.mrope else None)
        new_cache = dict(cache)
        new_cache["self"] = new_self
        if cfg.parallel_residual and not use_moe:
            f, _ = _ffn(p, x1, ctx)
            x1 = x1 + a + f
        else:
            x1 = x1 + a
            if cross:
                hx = apply_norm(p["ln_x"], x1, cfg.norm)
                cx = attn.decode_cross(p["cross"], hx, cache["cross"],
                                       n_heads=cfg.n_heads, head_dim=hd)
                x1 = x1 + cx
            f, _ = _ffn(p, x1, ctx)
            x1 = x1 + f
        if shared_after:
            x1 = _apply_shared_block(ctx["shared_params"], x1, ctx, cfg,
                                     decode=True)
        return x1, new_cache

    return Segment(kind, 1, init_unit, apply_unit, init_cache, decode_unit)


def ctx_enc_len(cfg: ModelConfig) -> int:
    return cfg.n_frontend_tokens or 1024


# ---------------------------------------------------------------------------
# zamba2 shared attention block
# ---------------------------------------------------------------------------

def init_shared_block(key, cfg):
    d, dt = cfg.d_model, jnp.dtype(cfg.param_dtype)
    hd = cfg.resolved_head_dim
    ks = split(key, 5)
    return {
        "in_proj": dense_init(ks[0], 2 * d, d, dt),   # concat(hidden, embed)
        "ln1": init_norm(cfg.norm, d, dt),
        "attn": attn.init_gqa(ks[1], d, cfg.n_heads, cfg.n_kv_heads, hd, dt),
        "ln2": init_norm(cfg.norm, d, dt),
        "mlp": init_swiglu(ks[2], d, cfg.hybrid.shared_d_ff or cfg.d_ff, dt),
        "out_proj": dense_init(ks[3], d, d, dt),
    }


def _apply_shared_block(p, x, ctx, cfg, decode: bool = False):
    hd = cfg.resolved_head_dim
    u = jnp.concatenate([x, ctx["x0"] if not decode else ctx["x0_1"]],
                        axis=-1) @ p["in_proj"]
    h = apply_norm(p["ln1"], u, cfg.norm)
    if decode:
        # shared block re-attends within the running window of its own cache;
        # zamba2's shared block sees the full sequence — we keep a full cache
        # held in ctx (threaded through decode by model-level code).
        a, ctx["shared_cache"] = attn.decode_gqa(
            p["attn"], h, ctx["shared_cache"], ctx["index"],
            n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, head_dim=hd,
            rope_theta=cfg.rope_theta)
    else:
        a = attn.apply_gqa(p["attn"], h, ctx["positions"],
                           n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
                           head_dim=hd, rope_theta=cfg.rope_theta,
                           causal=True, window=0, chunk=ctx["chunk"])
    u = u + a
    u = u + apply_swiglu(p["mlp"], apply_norm(p["ln2"], u, cfg.norm))
    return x + u @ p["out_proj"]


# ---------------------------------------------------------------------------
# ssm units
# ---------------------------------------------------------------------------

def _mk_mamba_layer(cfg, *, shared_after: bool, kind: str):
    d, dt = cfg.d_model, jnp.dtype(cfg.param_dtype)

    def init_unit(key):
        ks = split(key, 2)
        return {"ln": init_norm(cfg.norm, d, dt),
                "mamba": ssm_lib.init_mamba2(ks[0], d, cfg.ssm, dt)}

    def apply_unit(p, x, ctx):
        h = apply_norm(p["ln"], x, cfg.norm)
        x = x + ssm_lib.apply_mamba2(p["mamba"], h, cfg.ssm, d_model=d)
        if shared_after:
            x = _apply_shared_block(ctx["shared_params"], x, ctx, cfg)
        return x, 0.0

    def init_cache(batch, s_max, dtype):
        return ssm_lib.init_mamba2_state(batch, d, cfg.ssm, dtype)

    def decode_unit(p, x1, cache, index, ctx):
        h = apply_norm(p["ln"], x1, cfg.norm)
        y, cache = ssm_lib.decode_mamba2(p["mamba"], h, cache, cfg.ssm,
                                         d_model=d)
        x1 = x1 + y
        if shared_after:
            x1 = _apply_shared_block(ctx["shared_params"], x1, ctx, cfg,
                                     decode=True)
        return x1, cache

    return Segment(kind, 1, init_unit, apply_unit, init_cache, decode_unit)


def _mk_xlstm_layer(cfg, *, slstm: bool, kind: str):
    d, dt = cfg.d_model, jnp.dtype(cfg.param_dtype)

    def init_unit(key):
        ks = split(key, 2)
        if slstm:
            return {"ln": init_norm(cfg.norm, d, dt),
                    "cell": ssm_lib.init_slstm(ks[0], d, cfg.ssm, dt)}
        return {"ln": init_norm(cfg.norm, d, dt),
                "cell": ssm_lib.init_mlstm(ks[0], d, cfg.ssm, dt)}

    def apply_unit(p, x, ctx):
        h = apply_norm(p["ln"], x, cfg.norm)
        fn = ssm_lib.apply_slstm if slstm else ssm_lib.apply_mlstm
        return x + fn(p["cell"], h, cfg.ssm, d_model=d), 0.0

    def init_cache(batch, s_max, dtype):
        fn = ssm_lib.init_slstm_state if slstm else ssm_lib.init_mlstm_state
        return fn(batch, d, cfg.ssm, dtype)

    def decode_unit(p, x1, cache, index, ctx):
        h = apply_norm(p["ln"], x1, cfg.norm)
        fn = ssm_lib.decode_slstm if slstm else ssm_lib.decode_mlstm
        y, cache = fn(p["cell"], h, cache, cfg.ssm, d_model=d)
        return x1 + y, cache

    return Segment(kind, 1, init_unit, apply_unit, init_cache, decode_unit)


# ---------------------------------------------------------------------------
# per-architecture segment lists
# ---------------------------------------------------------------------------

def build_segments(cfg: ModelConfig, decoder: bool = True) -> List[Segment]:
    """Returns the segment list (decoder stack; encoder handled separately)."""
    segs: List[Segment] = []
    if cfg.family in ("dense", "vlm"):
        specs = []
        for i in range(cfg.n_layers):
            if cfg.global_every and (i % cfg.global_every != cfg.global_every - 1):
                specs.append("local")
            elif cfg.global_every:
                specs.append("global")
            else:
                specs.append("global" if not cfg.sliding_window else "local")
        for kind, n in _rle(specs):
            w = cfg.sliding_window if kind == "local" else 0
            s = _mk_attn_layer(cfg, window=w, kind=kind)
            s.n = n
            segs.append(s)
    elif cfg.family == "audio":
        # decoder stack with cross attention
        s = _mk_attn_layer(cfg, window=0, cross=True, kind="xdec")
        s.n = cfg.n_layers
        segs.append(s)
    elif cfg.family == "moe":
        m = cfg.moe
        if m.first_k_dense:
            s = _mk_attn_layer(cfg, window=0, use_moe=False, kind="dense0")
            s.n = m.first_k_dense
            segs.append(s)
        s = _mk_attn_layer(cfg, window=0, use_moe=True, kind="moe")
        s.n = cfg.n_layers - m.first_k_dense
        segs.append(s)
    elif cfg.family == "hybrid":
        period = cfg.hybrid.shared_attn_period
        specs = ["mamba_shared" if (i % period == period - 1) else "mamba"
                 for i in range(cfg.n_layers)]
        for kind, n in _rle(specs):
            s = _mk_mamba_layer(cfg, shared_after=(kind == "mamba_shared"),
                                kind=kind)
            s.n = n
            segs.append(s)
    elif cfg.family == "ssm":
        unit = cfg.ssm.xlstm_unit
        specs = ["slstm" if (i % unit == unit - 1) else "mlstm"
                 for i in range(cfg.n_layers)]
        for kind, n in _rle(specs):
            s = _mk_xlstm_layer(cfg, slstm=(kind == "slstm"), kind=kind)
            s.n = n
            segs.append(s)
    else:
        raise ValueError(cfg.family)
    return segs


def build_encoder_segments(cfg: ModelConfig) -> List[Segment]:
    s = _mk_attn_layer(cfg, window=0, causal=False, kind="enc")
    s.n = cfg.n_enc_layers
    return [s]


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, rng) -> Dict[str, Any]:
    dt = jnp.dtype(cfg.param_dtype)
    keys = split(rng, 8)
    segs = build_segments(cfg)
    params: Dict[str, Any] = {
        "embed": embed_init(keys[0], cfg.vocab_size, cfg.d_model, dt),
        "final_norm": init_norm(cfg.norm, cfg.d_model, dt),
    }
    if not cfg.tie_embeddings:
        params["head"] = dense_init(keys[1], cfg.d_model, cfg.vocab_size, dt)
    seg_keys = split(keys[2], len(segs))
    params["segments"] = [
        jax.vmap(s.init_unit)(jax.random.split(k, s.n))
        for s, k in zip(segs, seg_keys)]
    if cfg.family == "hybrid":
        params["shared_block"] = init_shared_block(keys[3], cfg)
    if cfg.is_encdec:
        enc = build_encoder_segments(cfg)
        enc_keys = split(keys[4], len(enc))
        params["enc_segments"] = [
            jax.vmap(s.init_unit)(jax.random.split(k, s.n))
            for s, k in zip(enc, enc_keys)]
        params["enc_final_norm"] = init_norm(cfg.norm, cfg.d_model, dt)
    return params


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _run_segments(segs, seg_params, x, ctx, *, remat: bool = True):
    aux_total = jnp.zeros((), jnp.float32)
    x = shard_act(x, "act")
    for s, sp in zip(segs, seg_params):
        # close over ctx so its static leaves (chunk size) stay python ints
        unit = s.apply_unit
        body = (lambda p, x, _u=unit: _u(p, x, ctx))
        if remat:
            body = jax.checkpoint(body)
        if s.n == 1:
            # unscanned single unit (keeps shared-block ctx access simple)
            p1 = jax.tree.map(lambda a: a[0], sp)
            x, a = body(p1, x)
            aux_total = aux_total + a
            continue

        def scan_fn(carry, p, _body=body):
            x, aux = carry
            x, a = _body(p, x)
            x = shard_act(x, "act")
            return (x, aux + a), None

        (x, aux_total), _ = jax.lax.scan(scan_fn, (x, aux_total), sp)
    return x, aux_total


def _positions(B, S):
    return jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None, :], (B, S))


# activation sharding hook lives in models.layers (leaf module — the SSM/
# MoE blocks use it too); re-exported here for the launchers.
from repro.models.layers import set_activation_sharder, shard_act  # noqa: E402


def _vlm_mrope_positions(cfg, B, S):
    """(3,B,S): vision prefix uses (t=0, h, w) grid; text continues with
    t=h=w = running position (qwen2-vl)."""
    P = cfg.n_frontend_tokens
    gw = max(1, int(P ** 0.5))
    idx = jnp.arange(S, dtype=jnp.int32)
    is_txt = idx >= P
    t = jnp.where(is_txt, idx, 0)
    h = jnp.where(is_txt, idx, idx // gw)
    w = jnp.where(is_txt, idx, idx % gw)
    pos3 = jnp.stack([t, h, w])                   # (3,S)
    return jnp.broadcast_to(pos3[:, None, :], (3, B, S))


def make_ctx(cfg, B, S, params=None, x0=None):
    chunk = 512 if S >= 4096 else 0
    ctx: Dict[str, Any] = {"positions": _positions(B, S), "chunk": chunk}
    if cfg.mrope:
        ctx["mrope_positions"] = _vlm_mrope_positions(cfg, B, S)
    if cfg.family == "hybrid" and params is not None:
        ctx["shared_params"] = params["shared_block"]
        ctx["x0"] = x0
    return ctx


def embed_tokens(params, cfg, tokens):
    cd = jnp.dtype(cfg.compute_dtype)
    return params["embed"].astype(cd)[tokens] * (cfg.d_model ** 0.5 if cfg.name.startswith("gemma") else 1.0)


def logits_fn(params, cfg, x):
    h = apply_norm(params["final_norm"], x, cfg.norm)
    w = params["embed"].T if cfg.tie_embeddings else params["head"]
    w = shard_act(w, "head_w")        # (d, V): V -> "model", d -> "data"
    return shard_act(h @ w.astype(h.dtype), "logits")


def forward_hidden(params, cfg, batch, *, remat: bool = True):
    """Trunk only: returns (final hidden (B,S,d) pre-final-norm, aux)."""
    cd = jnp.dtype(cfg.compute_dtype)
    tokens = batch["tokens"]
    B, S = tokens.shape
    if cfg.is_encdec:
        mem = batch["frontend"].astype(cd)
        enc_ctx = make_ctx(cfg, B, mem.shape[1])
        mem, _ = _run_segments(build_encoder_segments(cfg),
                               params["enc_segments"], mem, enc_ctx,
                               remat=remat)
        mem = apply_norm(params["enc_final_norm"], mem, cfg.norm)
        x = embed_tokens(params, cfg, tokens)
        ctx = make_ctx(cfg, B, S, params, x)
        ctx["enc_memory"] = mem
        return _run_segments(build_segments(cfg), params["segments"], x,
                             ctx, remat=remat)
    x = embed_tokens(params, cfg, tokens)
    if cfg.modality == "vlm":
        P = batch["frontend"].shape[1]
        x = jnp.concatenate([batch["frontend"].astype(cd), x[:, P:]], axis=1)
    ctx = make_ctx(cfg, B, S, params, x)
    return _run_segments(build_segments(cfg), params["segments"], x, ctx,
                         remat=remat)


def forward(params, cfg, batch, *, remat: bool = True):
    """Full-sequence logits (tests / small models)."""
    x, aux = forward_hidden(params, cfg, batch, remat=remat)
    return logits_fn(params, cfg, x), aux


def _ce_from_hidden(params, cfg, h_c, tgt_c, mask_c):
    """CE over one sequence chunk: head matmul + vocab-parallel-friendly
    logsumexp/masked-select (no gather over the sharded vocab dim)."""
    lg = logits_fn(params, cfg, h_c).astype(jnp.float32)
    lse = jax.nn.logsumexp(lg, axis=-1)
    iota_v = jax.lax.broadcasted_iota(jnp.int32, lg.shape, lg.ndim - 1)
    tgt_logit = jnp.sum(jnp.where(iota_v == tgt_c[..., None], lg, 0.0),
                        axis=-1)
    nll = (lse - tgt_logit) * mask_c
    return nll.sum(), mask_c.sum()


def loss_fn(params, cfg, batch, *, remat: bool = True,
            loss_chunk: int = 1024):
    """Next-token CE. The head+CE is chunked over the sequence so the
    (B,S,V) f32 logits never materialize (the dominant activation at 100k+
    vocabs); backward recomputes per chunk under remat."""
    h, aux = forward_hidden(params, cfg, batch, remat=remat)
    tokens = batch["tokens"]
    B, S = tokens.shape
    tgt = jnp.concatenate(
        [tokens[:, 1:], jnp.zeros((B, 1), tokens.dtype)], axis=1)
    mask = jnp.concatenate(
        [jnp.ones((B, S - 1), jnp.float32), jnp.zeros((B, 1), jnp.float32)],
        axis=1)
    if cfg.modality == "vlm":
        # only text positions (after the patch prefix) carry LM loss
        P = cfg.n_frontend_tokens
        pos = jnp.arange(S)[None, :]
        mask = mask * (pos >= P).astype(jnp.float32)

    if S % loss_chunk == 0 and S > loss_chunk:
        n = S // loss_chunk
        hs = h.reshape(B, n, loss_chunk, -1).transpose(1, 0, 2, 3)
        ts = tgt.reshape(B, n, loss_chunk).transpose(1, 0, 2)
        ms = mask.reshape(B, n, loss_chunk).transpose(1, 0, 2)
        body = jax.checkpoint(
            lambda hc, tc, mc: _ce_from_hidden(params, cfg, hc, tc, mc))
        sums = jax.lax.map(lambda args: body(*args), (hs, ts, ms))
        total, cnt = sums[0].sum(), sums[1].sum()
    else:
        total, cnt = _ce_from_hidden(params, cfg, h, tgt, mask)
    loss = total / jnp.maximum(cnt, 1.0)
    return loss + aux, {"nll": loss, "aux": aux}


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def init_decode_state(cfg: ModelConfig, batch: int, s_max: int,
                      dtype=None) -> Dict[str, Any]:
    dt = jnp.dtype(dtype or cfg.param_dtype)
    segs = build_segments(cfg)
    caches = [jax.vmap(lambda _ , s=s: s.init_cache(batch, s_max, dt))(
        jnp.arange(s.n)) for s in segs]
    state: Dict[str, Any] = {"caches": caches,
                             "index": jnp.zeros((), jnp.int32)}
    if cfg.family == "hybrid":
        state["shared_cache"] = attn.init_gqa_cache(
            batch, s_max, cfg.n_kv_heads, cfg.resolved_head_dim, dtype=dt)
    if cfg.is_encdec:
        state["enc_memory"] = jnp.zeros(
            (batch, ctx_enc_len(cfg), cfg.d_model), dt)
    return state


def decode_step(params, cfg: ModelConfig, state, tokens, embeds=None):
    """tokens: (B,1) current token (or ``embeds`` (B,1,d) for frontend
    positions of a VLM prefill-by-decode). Returns (logits, new_state)."""
    cd = jnp.dtype(cfg.compute_dtype)
    B = tokens.shape[0]
    index = state["index"]
    x1 = embeds.astype(cd) if embeds is not None \
        else embed_tokens(params, cfg, tokens)
    x1 = shard_act(x1, "act")
    ctx: Dict[str, Any] = {"chunk": 0, "index": index,
                           "positions": jnp.full((B, 1), index, jnp.int32)}
    if cfg.mrope:
        # same (t,h,w) mapping as the forward path, evaluated at `index`
        P = cfg.n_frontend_tokens
        gw = max(1, int(P ** 0.5))
        is_txt = index >= P
        t = jnp.where(is_txt, index, 0)
        h = jnp.where(is_txt, index, index // gw)
        w = jnp.where(is_txt, index, index % gw)
        pos3 = jnp.broadcast_to(jnp.stack([t, h, w])[:, None, None], (3, B, 1))
        ctx["mrope_positions"] = pos3.astype(jnp.int32)
    if cfg.family == "hybrid":
        ctx["shared_params"] = params["shared_block"]
        ctx["x0_1"] = x1
        ctx["shared_cache"] = state["shared_cache"]
    if cfg.is_encdec:
        ctx["enc_memory"] = state["enc_memory"]

    segs = build_segments(cfg)
    new_caches = []
    for s, sp, cache in zip(segs, params["segments"], state["caches"]):
        if s.n == 1:
            # unscanned: lets shared-block cache updates thread through ctx
            p1 = jax.tree.map(lambda a: a[0], sp)
            c1 = jax.tree.map(lambda a: a[0], cache)
            x1, nc1 = s.decode_unit(p1, x1, c1, index, ctx)
            new_caches.append(jax.tree.map(lambda a: a[None], nc1))
            continue

        def scan_fn(x1, pc, _s=s):
            p, c = pc
            x1, c = _s.decode_unit(p, x1, c, index, ctx)
            return x1, c

        x1, nc = jax.lax.scan(scan_fn, x1, (sp, cache))
        new_caches.append(nc)
    logits = logits_fn(params, cfg, x1)
    new_state = dict(state)
    new_state["caches"] = new_caches
    new_state["index"] = index + 1
    if cfg.family == "hybrid":
        new_state["shared_cache"] = ctx["shared_cache"]
    return logits, new_state


def prefill_encoder(params, cfg, frontend, *, remat=False):
    """Audio serving: run the encoder once, fill cross-attn caches."""
    cd = jnp.dtype(cfg.compute_dtype)
    mem = frontend.astype(cd)
    enc_ctx = make_ctx(cfg, mem.shape[0], mem.shape[1])
    mem, _ = _run_segments(build_encoder_segments(cfg),
                           params["enc_segments"], mem, enc_ctx, remat=remat)
    return apply_norm(params["enc_final_norm"], mem, cfg.norm)


def fill_cross_caches(params, cfg, state, enc_memory):
    """Precompute cross-attention K/V from encoder memory for every decoder
    layer (stacked over the segment scan dim)."""
    hd = cfg.resolved_head_dim
    segs = build_segments(cfg)
    new_caches = []
    for s, sp, cache in zip(segs, params["segments"], state["caches"]):
        def kv_fn(p):
            return attn.cross_kv(p["cross"], enc_memory,
                                 n_kv=cfg.n_kv_heads, head_dim=hd)
        kv = jax.vmap(kv_fn)(sp)
        c = dict(cache)
        c["cross"] = kv
        new_caches.append(c)
    state = dict(state)
    state["caches"] = new_caches
    state["enc_memory"] = enc_memory
    return state


# ---------------------------------------------------------------------------
# parameter counting (eval_shape — no allocation)
# ---------------------------------------------------------------------------

def count_params(cfg: ModelConfig) -> int:
    import math
    shapes = jax.eval_shape(lambda k: init_params(cfg, k),
                            jax.ShapeDtypeStruct((2,), jnp.uint32))
    return sum(math.prod(x.shape) for x in jax.tree.leaves(shapes))


def count_active_params(cfg: ModelConfig) -> int:
    """MoE-aware active-parameter count (routed experts scaled by top_k/E)."""
    import math
    shapes = jax.eval_shape(lambda k: init_params(cfg, k),
                            jax.ShapeDtypeStruct((2,), jnp.uint32))
    total = 0
    scale_paths = ("experts",)

    def visit(path, leaf):
        nonlocal total
        n = math.prod(leaf.shape)
        names = [getattr(p, "key", getattr(p, "name", "")) for p in path]
        if cfg.moe and any(k in names for k in scale_paths):
            n = int(n * cfg.moe.top_k / cfg.moe.n_experts)
        total += n

    jax.tree_util.tree_map_with_path(visit, shapes)
    return total


def count_params_analytic(cfg: ModelConfig) -> int:
    return count_params(cfg)
