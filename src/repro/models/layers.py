"""Shared building blocks: norms, RoPE/M-RoPE, MLPs, initializers.

All modules are functional: ``init_*`` returns a param dict, ``apply``-style
functions consume it. Stacked (scanned) layers carry a leading layer dim.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# activation sharding hook (installed by the mesh launcher; identity on CPU)
# ---------------------------------------------------------------------------

_ACT_SHARDER = None


def set_activation_sharder(fn):
    global _ACT_SHARDER
    _ACT_SHARDER = fn


def shard_act(x, name: str):
    if _ACT_SHARDER is None:
        return x
    return _ACT_SHARDER(name, x)


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype=jnp.float32):
    scale = 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), dtype=jnp.float32) * scale
            ).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype=jnp.float32):
    return (jax.random.normal(key, (vocab, d), dtype=jnp.float32) * 0.02
            ).astype(dtype)


def split(key, n):
    return list(jax.random.split(key, n))


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def init_norm(kind: str, d: int, dtype=jnp.float32):
    if kind == "rmsnorm":
        return {"scale": jnp.ones((d,), dtype)}
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def apply_norm(params, x, kind: str, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
        return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE (+ M-RoPE for qwen2-vl)
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float
               ) -> jnp.ndarray:
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    inv = rope_freqs(d, theta)                       # (d/2,)
    ang = positions[..., None].astype(jnp.float32) * inv   # (..., S, d/2)
    cos = jnp.cos(ang)[..., None, :]                 # (..., S, 1, d/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jnp.ndarray, positions3: jnp.ndarray, theta: float,
                sections: Tuple[int, int, int]) -> jnp.ndarray:
    """Qwen2-VL multimodal RoPE. positions3: (3, ..., S) = (t, h, w) ids.
    The head_dim/2 frequency slots are split into `sections` groups, each
    rotated by its own position stream (arXiv:2409.12191)."""
    d = x.shape[-1]
    half = d // 2
    assert sum(sections) == half, (sections, half)
    inv = rope_freqs(d, theta)                       # (half,)
    # build a (..., S, half) angle tensor, per-section position source
    angs = []
    start = 0
    for sec_i, sec in enumerate(sections):
        pos = positions3[sec_i]                      # (..., S)
        angs.append(pos[..., None].astype(jnp.float32) * inv[start:start + sec])
        start += sec
    ang = jnp.concatenate(angs, axis=-1)             # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def init_swiglu(key, d: int, d_ff: int, dtype=jnp.float32):
    k1, k2, k3 = split(key, 3)
    return {"w_gate": dense_init(k1, d, d_ff, dtype),
            "w_up": dense_init(k2, d, d_ff, dtype),
            "w_down": dense_init(k3, d_ff, d, dtype)}


def apply_swiglu(p, x):
    h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    return h @ p["w_down"]


def init_gelu_mlp(key, d: int, d_ff: int, dtype=jnp.float32):
    k1, k2 = split(key, 2)
    return {"w_in": dense_init(k1, d, d_ff, dtype),
            "b_in": jnp.zeros((d_ff,), dtype),
            "w_out": dense_init(k2, d_ff, d, dtype),
            "b_out": jnp.zeros((d,), dtype)}


def apply_gelu_mlp(p, x):
    h = jax.nn.gelu(x @ p["w_in"] + p["b_in"])
    return h @ p["w_out"] + p["b_out"]
