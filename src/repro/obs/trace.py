"""Chrome-trace-event / Perfetto export of per-round phase spans.

Both sim backends attach a flat span list to every ``RoundEvent``
(``RoundEvent.spans``): tuples ``(name, cluster, start_s, dur_s)`` with
``start_s`` relative to the round's own start.  The in-process simulator
records **modeled** spans (derived from the same
``topology/accounting.compute_leg`` arithmetic that fills the timing
fields); proc workers time their **measured** phases with
``time.monotonic`` and ship the records inside the existing round-report
payload.  This module is a pure consumer: it lays the spans out on a
global clock (cumulative ``t_round_s`` offsets) and emits the Chrome
trace-event JSON that ``chrome://tracing`` / https://ui.perfetto.dev
load directly.

Span taxonomy (one lane pair per cluster):

  ===========  =====  =================================================
  name         lane   meaning
  ===========  =====  =================================================
  inner        0      H local AdamW steps (the compute leg)
  idle         0      barrier wait after own compute (straggler waste)
  stale_wait   0      bounded_stale: staleness-gate wait after the leg
                      (the async replacement for barrier ``idle``)
  leg          0      bounded_stale: per-cluster leg envelope (compute
                      + gate wait); carries the commit's ``staleness``
                      and ``round_clock`` in its ``args``
  compress     1      compressor round-trip on the outgoing delta
  wire         1      payload on the wire (socket send / p2p exchange);
                      in bounded_stale mode the publish is emitted as a
                      ``b``/``e`` async pair because it legitimately
                      overlaps the gate wait and the next leg (§2.3
                      generalized)
  mix          1      applying the returned average / neighbor mixing
  outer        1      EF + outer Nesterov + param hash
  gather       1      coordinator-side gather phase (pid = coordinator)
  round        0      barrier mode: per-round envelope (pid =
                      coordinator row); its ``args`` carry the round's
                      comm accounting
  ===========  =====  =================================================

Lane 0 holds compute-side spans and lane 1 comm-side spans, so spans
nest without overlap within a ``(pid, tid)`` row even in delay mode
(where the comm thread genuinely runs concurrently with compute).

Clock layout depends on the outer-sync policy.  Barrier timelines place
round ``r`` at the cumulative ``t_round_s`` offset and wrap it in a
coordinator-row ``round`` envelope.  Bounded-stale timelines have no
global round — each event is one cluster's commit, placed at its own
``RoundEvent.t_start_s`` on the cluster's row, so Perfetto shows the
per-cluster round clocks drifting apart and re-converging; the ``leg``
span is the envelope and there is no coordinator round row.

``trace_fingerprint`` hashes the *structural* shape of a trace — event
names/categories/rows/round tags, never ``ts``/``dur`` — so identical-
seed runs compare equal even when wall clock differs.
"""
from __future__ import annotations

import hashlib
import json
import sys
import threading
import time
from contextlib import contextmanager
from typing import Any, Dict, List, Optional

# pid of the coordinator/global row (clusters use their own id)
COORD_PID = 9999

_LANES = {"inner": 0, "idle": 0, "round": 0, "stale_wait": 0, "leg": 0,
          "compress": 1, "wire": 1, "mix": 1, "outer": 1, "gather": 1}


def _meta(kind: str, pid: int, name: str, tid: int = 0) -> Dict[str, Any]:
    return {"name": kind, "ph": "M", "ts": 0, "dur": 0, "pid": pid,
            "tid": tid, "args": {"name": name}}


def timeline_trace(tl: Any) -> Dict[str, Any]:
    """Convert a ``Timeline`` (either backend) to a Chrome trace dict.

    Every complete event carries ``args.round``; the per-round ``round``
    envelope on the coordinator row additionally carries the round's comm
    accounting (``t_comm_s`` / ``hidden_comm_s`` / ``exposed_comm_s`` /
    ``wire_bytes``) so the trace is self-describing in Perfetto.
    """
    scenario = tl.scenario if isinstance(tl.scenario, dict) else {}
    backend = scenario.get("backend", "model")
    cat = "measured" if backend == "proc" else "modeled"
    events: List[Dict[str, Any]] = []
    pids_seen: Dict[int, set] = {}

    def emit(name: str, pid: int, start_s: float, dur_s: float,
             args: Dict[str, Any]) -> None:
        tid = _LANES.get(name, 1)
        events.append({"name": name, "cat": cat, "ph": "X",
                       "ts": round(start_s * 1e6, 3),
                       "dur": round(max(0.0, dur_s) * 1e6, 3),
                       "pid": pid, "tid": tid, "args": args})
        pids_seen.setdefault(pid, set()).add(tid)

    def emit_pub(pid: int, start_s: float, dur_s: float, rnd: int) -> None:
        # async publish: a b/e pair (Chrome async events MAY overlap,
        # complete events in a row must nest — and an in-flight send
        # genuinely overlaps the gate wait and the next leg)
        base = {"name": "wire", "cat": cat, "pid": pid, "tid": 1,
                "id": int(rnd), "args": {"round": int(rnd)}}
        events.append({**base, "ph": "b", "ts": round(start_s * 1e6, 3)})
        events.append({**base, "ph": "e",
                       "ts": round((start_s + max(0.0, dur_s)) * 1e6, 3)})
        pids_seen.setdefault(pid, set()).add(1)

    is_async = any(e.t_start_s is not None for e in tl.events)
    offset = 0.0
    for e in tl.events:
        hidden = max(0.0, e.t_comm_s - e.exposed_comm_s)
        if is_async:
            # per-cluster round clocks: place the commit at its own leg
            # start; the cluster-row "leg" span is the envelope (there is
            # no global round, so no coordinator round row)
            off = float(e.t_start_s or 0.0)
        else:
            off = offset
            emit("round", COORD_PID, off, e.t_round_s,
                 {"round": e.round, "t_comm_s": round(e.t_comm_s, 6),
                  "hidden_comm_s": round(hidden, 6),
                  "exposed_comm_s": round(e.exposed_comm_s, 6),
                  "wire_bytes": e.wire_bytes})
            offset += e.t_round_s
        for span in (e.spans or ()):
            name, cluster, start_s, dur_s = span
            pid = COORD_PID if int(cluster) < 0 else int(cluster)
            if is_async and str(name) == "wire":
                emit_pub(pid, off + float(start_s), float(dur_s), e.round)
                continue
            args: Dict[str, Any] = {"round": e.round}
            if is_async and str(name) == "leg":
                args.update(
                    cluster=e.cluster,
                    staleness={int(p): int(s)
                               for p, s in (e.staleness or ())},
                    round_clock=list(e.round_clock or ()))
            emit(str(name), pid, off + float(start_s), float(dur_s), args)

    meta: List[Dict[str, Any]] = []
    for pid in sorted(pids_seen):
        pname = ("coordinator" if pid == COORD_PID else f"cluster {pid}")
        meta.append(_meta("process_name", pid, pname))
        for tid in sorted(pids_seen[pid]):
            if pid == COORD_PID:
                tname = "rounds" if tid == 0 else "gather"
            else:
                tname = "compute" if tid == 0 else "comm"
            meta.append(_meta("thread_name", pid, tname, tid))

    return {"traceEvents": meta + events, "displayTimeUnit": "ms",
            "otherData": {"backend": backend, "category": cat,
                          "n_rounds": len(tl.events)}}


def validate_chrome_trace(trace: Any) -> List[str]:
    """Schema check; returns a list of error strings (empty = valid).

    Checks: the dict serializes to JSON, ``traceEvents`` is a list of
    objects each carrying ``name``/``ph``/``ts``/``pid``/``tid`` (plus a
    non-negative ``dur`` for complete events), and within every
    ``(pid, tid)`` row the complete events nest without partial overlap.
    """
    errs: List[str] = []
    if not isinstance(trace, dict):
        return ["trace is not a JSON object"]
    try:
        json.dumps(trace)
    except (TypeError, ValueError) as e:
        errs.append(f"trace is not JSON-serializable: {e}")
    evs = trace.get("traceEvents")
    if not isinstance(evs, list):
        return errs + ["traceEvents missing or not a list"]

    lanes: Dict[Any, List] = {}
    for i, ev in enumerate(evs):
        if not isinstance(ev, dict):
            errs.append(f"event {i}: not an object")
            continue
        for k in ("name", "ph", "ts", "pid", "tid"):
            if k not in ev:
                errs.append(f"event {i}: missing {k!r}")
        if ev.get("ph") == "X":
            if not isinstance(ev.get("dur"), (int, float)):
                errs.append(f"event {i}: complete event needs numeric "
                            f"'dur' (got {ev.get('dur')!r})")
            elif ev["dur"] < 0:
                errs.append(f"event {i}: negative dur")
            elif isinstance(ev.get("ts"), (int, float)):
                lanes.setdefault((ev.get("pid"), ev.get("tid")), []).append(
                    (float(ev["ts"]), float(ev["dur"]), i))
            else:
                errs.append(f"event {i}: non-numeric ts")

    eps = 1.0  # µs of float-rounding slack
    for (pid, tid), rows in lanes.items():
        rows.sort(key=lambda t: (t[0], -t[1]))
        stack: List[float] = []          # open span end times
        for ts, dur, i in rows:
            while stack and ts >= stack[-1] - eps:
                stack.pop()
            if stack and ts + dur > stack[-1] + eps:
                errs.append(f"event {i}: span overlaps (not nested in) "
                            f"the enclosing span in row pid={pid} "
                            f"tid={tid}")
                continue
            stack.append(ts + dur)
    return errs


def trace_fingerprint(trace: Dict[str, Any]) -> str:
    """Structural hash of a trace: event names, phases, categories, rows,
    and round tags — never ``ts``/``dur`` or any other wall-clock field.
    Identical-seed runs must produce identical structural fingerprints on
    the in-process backend; proc runs are wall-clock-noisy but keep the
    same row/name structure for a deterministic scenario."""
    rows = [[ev.get("ph"), ev.get("name"), ev.get("cat"), ev.get("pid"),
             ev.get("tid"), (ev.get("args") or {}).get("round")]
            for ev in trace.get("traceEvents", [])
            if isinstance(ev, dict)]
    blob = json.dumps(rows, sort_keys=True).encode("utf-8")
    return hashlib.sha256(blob).hexdigest()


def save(trace: Dict[str, Any], path: str) -> None:
    with open(path, "w") as f:
        json.dump(trace, f, indent=1)


class Tracer:
    """Wall-clock span recorder for driver code (``launch/train.py``):
    ``with tracer.span("outer"): ...`` records a measured complete event.
    Threads map to tids in first-seen order, so concurrent spans land on
    separate rows and the nesting invariant holds per row."""

    def __init__(self, process: str = "driver", pid: int = 0):
        self.pid = pid
        self.process = process
        self._t0 = time.monotonic()
        self._tids: Dict[int, int] = {}
        self._lock = threading.Lock()
        self.events: List[Dict[str, Any]] = []

    def _tid(self) -> int:
        key = threading.get_ident()
        with self._lock:
            return self._tids.setdefault(key, len(self._tids))

    @contextmanager
    def span(self, name: str, **args: Any):
        start = time.monotonic()
        try:
            yield
        finally:
            end = time.monotonic()
            ev = {"name": name, "cat": "measured", "ph": "X",
                  "ts": round((start - self._t0) * 1e6, 3),
                  "dur": round((end - start) * 1e6, 3),
                  "pid": self.pid, "tid": self._tid()}
            if args:
                ev["args"] = args
            with self._lock:
                self.events.append(ev)

    def trace(self) -> Dict[str, Any]:
        meta = [_meta("process_name", self.pid, self.process)]
        for tid in sorted(self._tids.values()):
            meta.append(_meta("thread_name", self.pid,
                              f"thread {tid}", tid))
        return {"traceEvents": meta + list(self.events),
                "displayTimeUnit": "ms"}

    def write(self, path: str) -> None:
        save(self.trace(), path)


def main(argv: Optional[List[str]] = None) -> None:
    """CLI validator: ``python -m repro.obs.trace FILE...`` exits non-zero
    if any file fails the Chrome-trace schema check."""
    argv = sys.argv[1:] if argv is None else argv
    if not argv:
        print("usage: python -m repro.obs.trace TRACE.json [...]",
              file=sys.stderr)
        sys.exit(2)
    bad = 0
    for path in argv:
        try:
            with open(path) as f:
                trace = json.load(f)
        except (OSError, ValueError) as e:
            print(f"{path}: unreadable ({e})")
            bad += 1
            continue
        errs = validate_chrome_trace(trace)
        if errs:
            bad += 1
            print(f"{path}: INVALID ({len(errs)} errors)")
            for e in errs[:20]:
                print(f"  - {e}")
        else:
            n = sum(1 for ev in trace.get("traceEvents", [])
                    if ev.get("ph") == "X")
            print(f"{path}: ok ({n} spans, fingerprint "
                  f"{trace_fingerprint(trace)[:16]})")
    sys.exit(1 if bad else 0)


if __name__ == "__main__":
    main()
