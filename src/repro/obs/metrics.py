"""Metrics registry: counters / gauges / histograms over ``RoundEvent``s.

``MetricsRegistry.observe_round(event)`` folds one round into the
standard metric set (wire bytes, hidden/exposed comm seconds, barrier
idle, tokens, loss, compressor rank, fault count) and records a flat
per-round dict for the JSONL sink.  Two exports:

 - ``write_jsonl(path)`` — one JSON object per round (the machine-
   readable per-round record, schema-stable across backends);
 - ``prometheus_text()`` — the final counters/gauges/histograms in
   Prometheus text exposition format (written once per run; point a
   file-based scraper or ``promtool`` at it).

Pure-python, jax-free, and strictly read-only: nothing here feeds back
into the round math.
"""
from __future__ import annotations

import json
import math
from typing import Any, Dict, List, Optional, Sequence, Tuple

_DEF_BUCKETS = (0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 30.0, 60.0)


class Counter:
    kind = "counter"

    def __init__(self, name: str, help: str = ""):
        self.name, self.help, self.value = name, help, 0.0

    def inc(self, v: float = 1.0) -> None:
        if v < 0:
            raise ValueError(f"counter {self.name} cannot decrease")
        self.value += v


class Gauge:
    kind = "gauge"

    def __init__(self, name: str, help: str = ""):
        self.name, self.help, self.value = name, help, 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 buckets: Sequence[float] = _DEF_BUCKETS):
        self.name, self.help = name, help
        self.buckets: Tuple[float, ...] = tuple(sorted(buckets))
        self.counts = [0] * (len(self.buckets) + 1)  # +1 for +Inf
        self.sum = 0.0
        self.count = 0

    def observe(self, v: float) -> None:
        self.sum += v
        self.count += 1
        for i, le in enumerate(self.buckets):
            if v <= le:
                self.counts[i] += 1
                return
        self.counts[-1] += 1


class MetricsRegistry:
    """Create-or-get metric accessors plus the standard round fold."""

    def __init__(self, run_meta: Optional[Dict[str, Any]] = None):
        self._metrics: Dict[str, Any] = {}
        self.run_meta = dict(run_meta or {})
        self.round_records: List[Dict[str, Any]] = []

    def _get(self, cls, name: str, **kw):
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = cls(name, **kw)
        elif not isinstance(m, cls):
            raise TypeError(f"metric {name!r} already registered as "
                            f"{m.kind}")
        return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(Counter, name, help=help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(Gauge, name, help=help)

    def histogram(self, name: str, help: str = "",
                  buckets: Sequence[float] = _DEF_BUCKETS) -> Histogram:
        return self._get(Histogram, name, help=help, buckets=buckets)

    # ---- the standard RoundEvent fold -------------------------------------
    def observe_round(self, e: Any) -> Dict[str, Any]:
        """Fold one ``RoundEvent``; returns (and records) the flat
        per-round dict the JSONL sink writes."""
        hidden = max(0.0, e.t_comm_s - e.exposed_comm_s)
        idle = sum(e.idle_by) if e.idle_by is not None else 0.0
        self.counter("repro_rounds_total",
                     "outer rounds completed").inc()
        self.counter("repro_wire_bytes_total",
                     "bytes crossing all links").inc(
            float(e.wire_bytes_total or e.wire_bytes))
        self.counter("repro_compute_seconds_total",
                     "barrier compute seconds").inc(e.t_compute_s)
        self.counter("repro_hidden_comm_seconds_total",
                     "comm seconds overlapped behind compute").inc(hidden)
        self.counter("repro_exposed_comm_seconds_total",
                     "comm seconds on the critical path").inc(
            e.exposed_comm_s)
        self.counter("repro_barrier_idle_seconds_total",
                     "cluster-seconds idling at the round barrier").inc(
            idle)
        self.counter("repro_tokens_total", "tokens trained").inc(e.tokens)
        self.counter("repro_faults_total", "fault tags observed").inc(
            len(e.faults))
        self.gauge("repro_alive_clusters",
                   "clusters alive last round").set(len(e.alive))
        if e.rank is not None:
            self.gauge("repro_compressor_rank",
                       "compressor rank r_t last round").set(e.rank)
        if e.loss is not None:
            self.gauge("repro_loss", "mean loss last round").set(e.loss)
        if e.disagreement is not None:
            self.gauge("repro_disagreement",
                       "gossip consensus RMS distance").set(e.disagreement)
        self.histogram("repro_round_seconds",
                       "round wall-clock seconds").observe(e.t_round_s)
        self.histogram("repro_exposed_comm_seconds",
                       "per-round exposed comm seconds").observe(
            e.exposed_comm_s)

        rec = {"round": e.round, "alive": list(e.alive),
               "h_steps": e.h_steps, "rank": e.rank,
               "t_compute_s": round(e.t_compute_s, 6),
               "t_comm_s": round(e.t_comm_s, 6),
               "hidden_comm_s": round(hidden, 6),
               "exposed_comm_s": round(e.exposed_comm_s, 6),
               "t_round_s": round(e.t_round_s, 6),
               "barrier_idle_s": round(idle, 6),
               "wire_bytes": e.wire_bytes,
               "wire_bytes_total": e.wire_bytes_total,
               "tokens": e.tokens, "loss": e.loss,
               "disagreement": e.disagreement,
               "ranks": (list(e.ranks) if e.ranks is not None else None),
               "faults": list(e.faults)}
        self.round_records.append(rec)
        return rec

    def observe_timeline(self, tl: Any) -> None:
        for e in tl.events:
            self.observe_round(e)

    # ---- exports ----------------------------------------------------------
    def write_jsonl(self, path: str) -> None:
        with open(path, "w") as f:
            if self.run_meta:
                f.write(json.dumps({"meta": self.run_meta},
                                   default=str) + "\n")
            for rec in self.round_records:
                f.write(json.dumps(rec, default=str) + "\n")

    def snapshot(self) -> Dict[str, Any]:
        out: Dict[str, Any] = {}
        for name, m in self._metrics.items():
            if isinstance(m, Histogram):
                out[name] = {"sum": m.sum, "count": m.count,
                             "buckets": dict(zip(
                                 [*map(str, m.buckets), "+Inf"],
                                 _cumulative(m.counts)))}
            else:
                out[name] = m.value
        return out

    def prometheus_text(self) -> str:
        lines: List[str] = []
        for name, m in self._metrics.items():
            if m.help:
                lines.append(f"# HELP {name} {m.help}")
            lines.append(f"# TYPE {name} {m.kind}")
            if isinstance(m, Histogram):
                cum = _cumulative(m.counts)
                for le, c in zip([*self._fmt_les(m.buckets), "+Inf"], cum):
                    lines.append(f'{name}_bucket{{le="{le}"}} {c}')
                lines.append(f"{name}_sum {_fmt(m.sum)}")
                lines.append(f"{name}_count {m.count}")
            else:
                lines.append(f"{name} {_fmt(m.value)}")
        return "\n".join(lines) + "\n"

    def write_prometheus(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.prometheus_text())

    @staticmethod
    def _fmt_les(buckets: Sequence[float]) -> List[str]:
        return [_fmt(b) for b in buckets]


def _cumulative(counts: Sequence[int]) -> List[int]:
    out, acc = [], 0
    for c in counts:
        acc += c
        out.append(acc)
    return out


def _fmt(v: float) -> str:
    if isinstance(v, float) and (math.isinf(v) or math.isnan(v)):
        return str(v)
    if float(v) == int(v):
        return str(int(v))
    return repr(float(v))
