"""Structured logger for the CLIs and trainers.

Replaces ad-hoc ``print()`` paths with one funnel that can emit the same
line two ways at once:

 - a **human-readable** line on a configurable stream (default stderr;
   the CLIs point it at stdout so their existing output — which tests and
   CI grep — stays byte-identical to the old ``print()``s);
 - an optional **machine-readable** JSON line per record on a second
   stream (``--log-json`` in the CLIs), carrying the structured fields
   that the human line flattens away.

No global logging-module state is touched: this is a tiny, explicit
funnel, not a ``logging`` wrapper, so importing it can never reconfigure
a host application's handlers.
"""
from __future__ import annotations

import json
import sys
import time
from typing import Any, Dict, Optional, TextIO

_LEVELS = {"debug": 10, "info": 20, "warning": 30, "error": 40}

_UNSET = object()


class _Config:
    def __init__(self) -> None:
        self.stream: Optional[TextIO] = None       # None -> sys.stderr
        self.json_stream: Optional[TextIO] = None  # None -> no JSON lines
        self.level: str = "info"


_cfg = _Config()


def configure(*, stream: Any = _UNSET, json_stream: Any = _UNSET,
              level: Any = _UNSET) -> None:
    """Point the human stream / JSON stream somewhere (or set the level).

    ``stream=None`` restores the stderr default; ``json_stream=None``
    disables JSON lines.  Only the keywords you pass change.
    """
    if stream is not _UNSET:
        _cfg.stream = stream
    if json_stream is not _UNSET:
        _cfg.json_stream = json_stream
    if level is not _UNSET:
        if level not in _LEVELS:
            raise ValueError(f"unknown log level {level!r}")
        _cfg.level = level


class Logger:
    """Named emitter.  ``info("text", k=v, ...)`` prints exactly ``text``
    on the human stream (so routed ``print()`` lines stay byte-identical
    — the values a human should see belong in the message itself) and,
    when configured, a JSON object carrying ``fields`` on the machine
    stream."""

    def __init__(self, name: str):
        self.name = name

    def log(self, level: str, msg: str, **fields: Any) -> None:
        if _LEVELS[level] < _LEVELS[_cfg.level]:
            return
        stream = _cfg.stream if _cfg.stream is not None else sys.stderr
        line = msg
        if _LEVELS[level] >= _LEVELS["warning"]:
            line = f"{level.upper()}: {line}"
        print(line, file=stream)
        if _cfg.json_stream is not None:
            rec: Dict[str, Any] = {"ts": round(time.time(), 6),
                                   "level": level, "logger": self.name,
                                   "msg": msg}
            rec.update(fields)
            print(json.dumps(rec, default=str), file=_cfg.json_stream)

    def debug(self, msg: str, **fields: Any) -> None:
        self.log("debug", msg, **fields)

    def info(self, msg: str, **fields: Any) -> None:
        self.log("info", msg, **fields)

    def warning(self, msg: str, **fields: Any) -> None:
        self.log("warning", msg, **fields)

    def error(self, msg: str, **fields: Any) -> None:
        self.log("error", msg, **fields)


_loggers: Dict[str, Logger] = {}


def get_logger(name: str) -> Logger:
    if name not in _loggers:
        _loggers[name] = Logger(name)
    return _loggers[name]
