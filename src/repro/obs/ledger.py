"""Overlap ledger: the §2.3 one-step-delay claim as per-round numbers.

DiLoCoX's central mechanism is that the outer sync's wire time hides
behind the next round's H local steps — ``exposed = max(0, T_comm −
H·T_step)``.  The ledger quantifies exactly that from a ``Timeline``:

 - ``hidden_comm_s``  = comm seconds overlapped behind compute
   (``t_comm − exposed``, clamped at 0 — on the proc backend the two are
   independent wall-clock measurements, so noise can push ``exposed``
   past ``t_comm``);
 - ``overlap_frac``   = hidden / t_comm per round (1.0 when the wire was
   silent);
 - ``overlap_efficiency`` = the run-level ratio Σhidden / Σcomm;
 - ``drift(measured, modeled)`` = per-round and cumulative
   measured−modeled round-time gap on the proc backend — how far real
   processes have slipped from the clock model that CI's equivalence
   tolerance is anchored to.

Bounded-stale timelines reuse the same ledger with an async reading:
each event is one cluster's commit (``LedgerRow.cluster`` is set), the
publish overlaps everything after the leg finishes, so ``exposed_comm_s``
is the *staleness-gate wait* — the only seconds a cluster ever stands
still — and ``hidden_comm_s = max(0, t_send − wait)`` is the wire time
genuinely behind compute.  ``barrier_idle_s`` rows then sum gate waits
in cluster-seconds, directly comparable to a barrier run of the same
scenario (the fleet benchmark's ≥50% idle-reduction gate).
"""
from __future__ import annotations

from dataclasses import asdict, dataclass
from typing import Any, Dict, List, Optional


@dataclass(frozen=True)
class LedgerRow:
    round: int
    t_compute_s: float
    t_comm_s: float
    hidden_comm_s: float
    exposed_comm_s: float
    overlap_frac: float
    barrier_idle_s: float
    t_round_s: float
    # bounded_stale: which cluster's commit this row is (None = barrier
    # round, where the row aggregates the whole fleet)
    cluster: Optional[int] = None


@dataclass
class OverlapLedger:
    rows: List[LedgerRow]

    @classmethod
    def from_timeline(cls, tl: Any) -> "OverlapLedger":
        rows = []
        for e in tl.events:
            hidden = max(0.0, e.t_comm_s - e.exposed_comm_s)
            rows.append(LedgerRow(
                round=e.round, t_compute_s=e.t_compute_s,
                t_comm_s=e.t_comm_s, hidden_comm_s=hidden,
                exposed_comm_s=e.exposed_comm_s,
                overlap_frac=(hidden / e.t_comm_s if e.t_comm_s > 0
                              else 1.0),
                barrier_idle_s=(sum(e.idle_by)
                                if e.idle_by is not None else 0.0),
                t_round_s=e.t_round_s,
                cluster=getattr(e, "cluster", None)))
        return cls(rows)

    # ---- run-level aggregates ---------------------------------------------
    @property
    def hidden_comm_s(self) -> float:
        return sum(r.hidden_comm_s for r in self.rows)

    @property
    def exposed_comm_s(self) -> float:
        return sum(r.exposed_comm_s for r in self.rows)

    @property
    def comm_s(self) -> float:
        return sum(r.t_comm_s for r in self.rows)

    @property
    def compute_s(self) -> float:
        return sum(r.t_compute_s for r in self.rows)

    @property
    def barrier_idle_s(self) -> float:
        return sum(r.barrier_idle_s for r in self.rows)

    @property
    def overlap_efficiency(self) -> float:
        """Fraction of all comm seconds hidden behind compute (1.0 when
        the wire was never busy: nothing needed hiding)."""
        c = self.comm_s
        return self.hidden_comm_s / c if c > 0 else 1.0

    def summary(self) -> str:
        return (f"overlap ledger: comm {self.comm_s:.3f}s = "
                f"hidden {self.hidden_comm_s:.3f}s + "
                f"exposed {self.exposed_comm_s:.3f}s "
                f"(efficiency {100 * self.overlap_efficiency:.1f}%), "
                f"barrier idle {self.barrier_idle_s:.3f} cluster-s")

    def to_dict(self) -> Dict[str, Any]:
        return {
            "summary": {
                "comm_s": round(self.comm_s, 6),
                "hidden_comm_s": round(self.hidden_comm_s, 6),
                "exposed_comm_s": round(self.exposed_comm_s, 6),
                "compute_s": round(self.compute_s, 6),
                "barrier_idle_s": round(self.barrier_idle_s, 6),
                "overlap_efficiency": round(self.overlap_efficiency, 6),
            },
            "rows": [asdict(r) for r in self.rows],
        }


def drift(measured: Any, modeled: Any) -> Dict[str, Any]:
    """Cumulative measured-vs-modeled round-time drift (proc backend).

    ``measured``/``modeled`` are Timelines of the *same scenario* (the
    pair ``check_equivalence`` produces).  Rounds are matched by index;
    a positive drift means real processes run slower than the clock
    model."""
    n = min(len(measured.events), len(modeled.events))
    per_round, cumulative, acc = [], [], 0.0
    for i in range(n):
        d = measured.events[i].t_round_s - modeled.events[i].t_round_s
        acc += d
        per_round.append(round(d, 6))
        cumulative.append(round(acc, 6))
    total_model = sum(e.t_round_s for e in modeled.events[:n])
    return {"per_round_s": per_round, "cumulative_s": cumulative,
            "final_drift_s": round(acc, 6),
            "final_drift_frac": (round(acc / total_model, 6)
                                 if total_model > 0 else 0.0)}
