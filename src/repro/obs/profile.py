"""Opt-in ``jax.profiler`` hooks, gated on ``REPRO_PROFILE=dir``.

With the env var unset every helper is a no-op (jax is never imported
from here — this module must stay importable in the jax-free timing-only
proc workers).  With ``REPRO_PROFILE=/some/dir``:

 - ``capture(name)`` wraps a region in ``jax.profiler.trace``, writing a
   TensorBoard-loadable profile to ``$REPRO_PROFILE/<name>``;
 - ``annotate(name)`` wraps a host-side region in
   ``jax.profiler.TraceAnnotation`` (shows up on the profiler's host
   timeline);
 - ``scope(name)`` returns ``jax.named_scope`` for *traced* code — the
   op names land in HLO metadata, so the pp inner engine and the Pallas
   kernel dispatch are findable in the captured device timeline.
"""
from __future__ import annotations

import contextlib
import os
from typing import Optional


def profile_dir() -> Optional[str]:
    d = os.environ.get("REPRO_PROFILE", "").strip()
    return d or None


def enabled() -> bool:
    return profile_dir() is not None


@contextlib.contextmanager
def capture(name: str):
    """Profile a region into ``$REPRO_PROFILE/<name>`` (no-op if unset)."""
    d = profile_dir()
    if d is None:
        yield
        return
    import jax
    path = os.path.join(d, name)
    os.makedirs(path, exist_ok=True)
    with jax.profiler.trace(path):
        yield


@contextlib.contextmanager
def annotate(name: str):
    """Host-timeline annotation around a region (no-op if unset)."""
    if not enabled():
        yield
        return
    import jax
    with jax.profiler.TraceAnnotation(name):
        yield


def scope(name: str):
    """``jax.named_scope`` for traced code paths (no-op if unset).
    Unlike the two region managers this *names ops* rather than timing a
    host region — use it inside functions that will be jitted."""
    if not enabled():
        return contextlib.nullcontext()
    import jax
    return jax.named_scope(name)
