"""Unified telemetry layer: span tracing, metrics, overlap ledger.

Everything here is strictly read-only off the numeric path — the modules
*consume* ``Timeline``/``RoundEvent`` data (or wall-clock measurements the
backends already take) and never feed anything back into the round math,
so the proc ≡ in-process bitwise gates are untouched by tracing.

 - ``obs.trace``   — Chrome-trace-event / Perfetto JSON export of the
   per-round phase spans both sim backends record (modeled on the
   in-process backend, measured wall clock on proc), plus a schema
   validator and a wall-clock ``Tracer`` for driver code.
 - ``obs.metrics`` — counters/gauges/histograms populated from
   ``RoundEvent`` fields, with a JSONL sink and Prometheus text
   exposition.
 - ``obs.ledger``  — the §2.3 overlap claim as numbers: per-round
   hidden/exposed comm seconds, overlap efficiency, modeled-vs-measured
   drift on the proc backend.
 - ``obs.log``     — structured logger replacing ad-hoc ``print()``
   paths (human-readable stream + optional JSON lines).
 - ``obs.profile`` — opt-in ``jax.profiler`` capture hooks
   (``REPRO_PROFILE=dir``); the only module that touches jax, lazily.

``import repro.obs`` stays jax-free: the proc backend's timing-only
workers must keep spawning without a jax import.
"""
from repro.obs.ledger import LedgerRow, OverlapLedger
from repro.obs.log import configure as configure_logging
from repro.obs.log import get_logger
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import (Tracer, timeline_trace, trace_fingerprint,
                             validate_chrome_trace)

__all__ = [
    "LedgerRow", "OverlapLedger", "MetricsRegistry", "Tracer",
    "configure_logging", "get_logger", "timeline_trace",
    "trace_fingerprint", "validate_chrome_trace",
]
