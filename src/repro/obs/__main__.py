"""Trace validator CLI: ``python -m repro.obs TRACE.json [...]``.

Exits non-zero if any file fails the Chrome-trace-event schema check
(see ``repro.obs.trace.validate_chrome_trace``).
"""
from repro.obs.trace import main

if __name__ == "__main__":
    main()
