"""Sharding-aware npz checkpointing (offline container: no orbax).

Saves the full pytree as flat npz entries keyed by the tree path, plus a
tiny json manifest (step, arch, ...). On restore the tree is rebuilt and
``jax.device_put`` re-applies target shardings if given. Values are pulled
with ``jax.device_get`` (gathers shards) — fine for the model scales we
execute on CPU; a production TPU deployment would swap in per-shard files
behind the same API.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for p, x in flat:
        arr = np.asarray(jax.device_get(x))
        if arr.dtype.kind not in 'fiub':          # bf16/void: store as f32
            arr = arr.astype(np.float32)
        out[jax.tree_util.keystr(p)] = arr
    return out


def save(path: str, tree, *, step: int = 0, meta: Optional[dict] = None):
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    arrays = _flatten(tree)
    np.savez(path + ".npz", **arrays)
    manifest = {"step": int(step), "n_arrays": len(arrays),
                "meta": meta or {}}
    with open(path + ".json", "w") as f:
        json.dump(manifest, f)


def restore(path: str, tree_like, *, shardings=None):
    """tree_like provides the structure; returns (tree, step)."""
    with np.load(path + ".npz") as data:
        flat, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
        leaves = []
        for p, ref in flat:
            key = jax.tree_util.keystr(p)
            arr = data[key]
            assert arr.shape == tuple(ref.shape), (key, arr.shape, ref.shape)
            leaves.append(jnp.asarray(arr).astype(ref.dtype))
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.device_put(tree, shardings)
    with open(path + ".json") as f:
        manifest = json.load(f)
    return tree, manifest["step"]


def latest(ckpt_dir: str) -> Optional[str]:
    if not os.path.isdir(ckpt_dir):
        return None
    cands = [f[:-5] for f in os.listdir(ckpt_dir) if f.endswith(".json")]
    if not cands:
        return None
    best = max(cands, key=lambda c: json.load(
        open(os.path.join(ckpt_dir, c + ".json")))["step"])
    return os.path.join(ckpt_dir, best)
