"""Topology & gossip-averaging subsystem.

Makes the outer-step communication pattern a first-class, pluggable
object: a ``Topology`` (ring / 2D torus / random k-regular / star / full)
yields per-round doubly-stochastic ``MixingMatrix`` weights, and
``mixing_op(topology, alive)`` produces the ``cluster_mean``-shaped
callable ``core.diloco.diloco_round`` consumes — gather kinds reproduce
the seed repo's hub average bit-for-bit, gossip kinds mix each cluster
with its graph neighbors only (NoLoCo-style neighbor averaging).

Importing this package is jax-free (graph/accounting arithmetic is numpy);
only the mix operators themselves touch jax, lazily.
"""
from repro.topology.accounting import (ComputeLeg, GossipComm, compute_leg,
                                       gossip_round_comm, round_wire_total)
from repro.topology.graphs import (GATHER_KINDS, GOSSIP_KINDS, KINDS,
                                   Digraph, Topology, as_digraph,
                                   directed_ring, full, make_topology, ring,
                                   random_regular, star, torus)
from repro.topology.mixing import (MixingMatrix, async_mix_weights,
                                   consensus_distance, mix_row, mix_stacked,
                                   mixing_op, push_sum_average,
                                   push_sum_round, push_sum_weights)

__all__ = [
    "Topology", "make_topology", "ring", "torus", "random_regular", "star",
    "full", "KINDS", "GATHER_KINDS", "GOSSIP_KINDS",
    "Digraph", "as_digraph", "directed_ring",
    "MixingMatrix", "mixing_op", "mix_row", "mix_stacked",
    "consensus_distance",
    "push_sum_weights", "push_sum_round", "push_sum_average",
    "async_mix_weights",
    "GossipComm", "gossip_round_comm", "round_wire_total",
    "ComputeLeg", "compute_leg",
]
