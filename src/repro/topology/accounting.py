"""Wire/time accounting for the outer sync under a topology.

One function pair shared *verbatim* by the in-process simulator and the
proc-backend coordinator, so the modeled timeline and the proc backend's
structural fields (bottleneck cluster, total bytes) can never drift apart.

Gather kinds keep the seed repo's arithmetic (ring all-gather charge of
``(n_alive-1) * payload`` per member over the bottleneck link).  Gossip
kinds charge each cluster ``deg * payload`` on its *own* (possibly
degraded) uplink — sends to each neighbor are serialized on that link —
and the round's comm time is the slowest cluster's exchange.

All numpy/python; importable without jax.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from repro.topology.graphs import Topology


@dataclass(frozen=True)
class ComputeLeg:
    """One round's compute side of the barrier, per cluster.

    ``t_by[c]`` is cluster c's own local-training seconds
    (``h_c * t_step_c``), ``t_barrier_s`` the round's compute leg (the
    slowest alive cluster — everyone waits at the outer sync), and
    ``idle_by[c]`` the barrier wait each cluster burns
    (``t_barrier_s - t_by[c]``) — the waste the heterogeneous-H scheduler
    exists to shrink.
    """
    t_barrier_s: float
    slowest_cluster: int               # argmax own compute time (-1: none)
    t_by: Dict[int, float]             # cluster -> own compute seconds
    idle_by: Dict[int, float]          # cluster -> barrier wait seconds


def compute_leg(h_by: Dict[int, int], t_steps: Sequence[float],
                alive: np.ndarray) -> ComputeLeg:
    """Per-round compute/barrier accounting for a (possibly per-cluster)
    local-step schedule ``h_by`` (``core.adaptive.plan_h`` output) over the
    alive set.  One implementation shared by the in-process simulator and
    the proc coordinator — the modeled compute targets, barrier time, and
    the ``slowest_cluster`` structural field can never drift between the
    backends.  Deterministic tie-break: first alive cluster with the max
    time wins (ascending-id ``max``, both backends)."""
    alive = np.asarray(alive, bool)
    ids = [int(i) for i in np.flatnonzero(alive)]
    if not ids:
        return ComputeLeg(0.0, -1, {}, {})
    t_by = {c: float(int(h_by[c]) * float(t_steps[c])) for c in ids}
    slowest = max(ids, key=lambda c: (t_by[c], -c))
    barrier = t_by[slowest]
    idle_by = {c: barrier - t_by[c] for c in ids}
    return ComputeLeg(barrier, int(slowest), t_by, idle_by)


@dataclass(frozen=True)
class GossipComm:
    t_comm_s: float                    # slowest cluster's neighbor exchange
    bottleneck_cluster: int            # argmax per-cluster comm time (-1)
    wire_bytes_total: int              # sum over links, both directions
    sends: Dict[int, int]              # cluster -> payloads it ships


def gossip_round_comm(topo: Topology, alive: np.ndarray, wire_bytes: int,
                      bws: Sequence[float], latency_s: float,
                      wire_by_cluster: Optional[Dict[int, int]] = None
                      ) -> GossipComm:
    """Per-round comm accounting for a gossip topology.

    ``bws`` is the per-cluster bandwidth *after* fault degradation/jitter
    (index = cluster id, dead entries ignored).  ``wire_by_cluster`` is the
    per-EDGE variant: cluster c ships ``wire_by_cluster[c]`` bytes per
    neighbor (the bandwidth-aware controller compresses a degraded uplink's
    edges harder); omitted, every sender ships ``wire_bytes``.
    Deterministic tie-break: first alive cluster with the max time wins,
    matching Python ``max`` over ascending ids on both backends.
    """
    alive = np.asarray(alive, bool)
    alive_ids = [int(i) for i in np.flatnonzero(alive)]
    w_of = (lambda c: int(wire_by_cluster[c])) if wire_by_cluster is not None \
        else (lambda c: int(wire_bytes))
    sends = {c: len(topo.alive_neighbors(c, alive)) for c in alive_ids}
    total = sum(sends[c] * w_of(c) for c in alive_ids)
    busy = [c for c in alive_ids if sends[c]]
    if not busy:
        return GossipComm(0.0, -1, 0, sends)
    t_of = lambda c: (sends[c] * w_of(c) / float(bws[c])
                      + sends[c] * latency_s)
    bottleneck = max(busy, key=lambda c: (t_of(c), -c))
    return GossipComm(float(t_of(bottleneck)), int(bottleneck), int(total),
                      sends)


def round_wire_total(mode: str, n_alive: int, wire_bytes: int,
                     h_steps: int = 1) -> int:
    """Total bytes crossing all links in one round for the non-gossip
    modes (gossip comes from ``gossip_round_comm``):

     - ``gather``: ring all-gather, every member forwards (n-1) payloads;
     - ``allreduce``: per-local-step ring allreduce, 2(n-1)/n * payload
       per member per step.
    """
    if n_alive < 2:
        return 0
    if mode == "gather":
        return n_alive * (n_alive - 1) * wire_bytes
    if mode == "allreduce":
        return int(h_steps * 2 * (n_alive - 1) * wire_bytes)
    raise ValueError(f"unknown wire mode {mode!r}")
