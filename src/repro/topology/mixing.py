"""Doubly-stochastic mixing matrices + the gossip mixing operator.

``MixingMatrix`` turns a ``Topology`` into the per-round averaging weights:

 - gather kinds (star/full): ``W = J/n`` — the exact global mean in one
   step (what the hub relay realizes), spectral gap 1;
 - gossip kinds: Metropolis-Hastings weights on the graph,
   ``W_ij = 1/(1 + max(d_i, d_j))`` on edges, self-weight absorbs the rest.
   Symmetric, nonnegative, rows sum to 1 => doubly stochastic, so repeated
   mixing contracts every cluster toward the mean at the rate of the
   spectral gap ``1 - |lambda_2|``.

Membership churn reuses ``core.membership.masked_mixing_matrix`` (row
renormalization: dead rows/cols masked, the self-weight absorbs the lost
mass) so the alive block stays symmetric doubly stochastic.

``mixing_op(topology, alive)`` produces the ``cluster_mean``-shaped callable
``core.diloco.diloco_round`` consumes.  For gather kinds it returns the
masked global mean (bit-identical to the seed's hub path); for gossip kinds
it returns a *stacked* tree — row c is cluster c's neighborhood average —
and is tagged ``returns_stacked=True`` so the round switches to gossip
semantics.

``mix_row``/``mix_stacked`` are deliberately unrolled scalar-weight
multiply-add chains (same trick as ``core.diloco.per_cluster_compress``):
a proc worker computing its own row and the in-process simulator computing
all rows execute the identical op sequence, which is what keeps the two
backends bit-for-bit equal.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional, Sequence

import numpy as np

from repro.topology.graphs import (GATHER_KINDS, Digraph, Topology,
                                   as_digraph)

# jax is imported lazily inside the mix operators: the coordinator and the
# timing-only workers import this module for the numpy-side accounting and
# must not pay (or require) a jax import.


# ---------------------------------------------------------------------------
# matrices (pure numpy)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MixingMatrix:
    """A (n, n) float32 mixing matrix tied to the topology that produced it.
    float32 on purpose: the same bytes feed both simulator backends."""
    W: np.ndarray
    kind: str = "custom"

    def __post_init__(self):
        W = np.asarray(self.W, np.float32)
        if W.ndim != 2 or W.shape[0] != W.shape[1]:
            raise ValueError(f"mixing matrix must be square, got {W.shape}")
        object.__setattr__(self, "W", W)

    @staticmethod
    def metropolis(topo: Topology,
                   alive: Optional[np.ndarray] = None) -> "MixingMatrix":
        """Metropolis-Hastings weights on the (alive-masked) graph.  Gather
        kinds get J/n over the alive set — one hub round IS the global
        mean, not an MH step on the star graph."""
        n = topo.n
        if topo.kind in ("star", "full"):
            W = np.full((n, n), 1.0 / n, np.float64)
        else:
            deg = np.array([topo.degree(c) for c in range(n)], np.float64)
            W = np.zeros((n, n), np.float64)
            for i, j in topo.edges:
                w = 1.0 / (1.0 + max(deg[i], deg[j]))
                W[i, j] = W[j, i] = w
            np.fill_diagonal(W, 1.0 - W.sum(axis=1))
        mm = MixingMatrix(W.astype(np.float32), topo.kind)
        if alive is not None:
            mm = mm.masked(alive)
        return mm

    def masked(self, alive: np.ndarray) -> "MixingMatrix":
        """Membership-masked row renormalization (core.membership)."""
        from repro.core.membership import masked_mixing_matrix

        W = np.asarray(masked_mixing_matrix(self.W, np.asarray(alive)),
                       np.float32)
        return MixingMatrix(W, self.kind)

    def is_doubly_stochastic(self, atol: float = 1e-5) -> bool:
        W = self.W.astype(np.float64)
        return bool((W >= -atol).all()
                    and np.allclose(W.sum(axis=0), 1.0, atol=atol)
                    and np.allclose(W.sum(axis=1), 1.0, atol=atol))

    def spectral_gap(self, alive: Optional[np.ndarray] = None) -> float:
        """1 - |lambda_2| of the (alive block of the) matrix: the per-mix
        contraction rate toward consensus.  Dead identity rows would each
        contribute a spurious eigenvalue 1, hence the restriction."""
        W = self.W.astype(np.float64)
        if alive is not None:
            ids = np.flatnonzero(np.asarray(alive, bool))
            W = W[np.ix_(ids, ids)]
        if W.shape[0] <= 1:
            return 1.0
        eig = np.sort(np.abs(np.linalg.eigvals(W)))[::-1]
        return float(1.0 - eig[1])


# ---------------------------------------------------------------------------
# push-sum: column-stochastic weights for directed/asymmetric graphs
# ---------------------------------------------------------------------------

def push_sum_weights(graph) -> np.ndarray:
    """Column-stochastic push-sum weights for a directed graph.

    ``W[i, j] = 1 / (out_degree(j) + 1)`` for every arc ``j -> i`` and for
    the self-loop ``j -> j``: node ``j`` splits its mass equally over its
    out-neighbors and itself, so every *column* sums to exactly 1 — total
    mass is conserved — with no symmetry (double stochasticity)
    requirement at all.  That is the whole point: Metropolis-Hastings
    weights need ``W = Wᵀ``, which an asymmetric-uplink WAN cannot
    provide; push-sum instead tracks a weight scalar ``φ`` through the
    same matrix and debiases with the ratio ``x/φ`` (Kempe et al.), which
    converges to the true average on any strongly connected digraph.

    Accepts a ``Digraph`` or an undirected ``Topology`` (promoted via
    ``as_digraph``).  float64, exact ``1/(d+1)`` rationals — both sim
    backends build the identical matrix.
    """
    g = graph if isinstance(graph, Digraph) else as_digraph(graph)
    n = g.n
    W = np.zeros((n, n), np.float64)
    for j in range(n):
        share = 1.0 / (g.out_degree(j) + 1.0)
        W[j, j] = share
        for i in g.out_neighbors(j):
            W[i, j] = share
    return W


def push_sum_round(W: np.ndarray, x: np.ndarray, phi: np.ndarray):
    """One synchronous push-sum iteration: ``x' = W x``, ``φ' = W φ``.
    ``x``: (n, ...) values, ``φ``: (n,) weights (init: ones).  The
    debiased estimate at any time is ``x / φ`` per node; column
    stochasticity conserves ``Σx`` and ``Σφ`` exactly."""
    x = np.asarray(x, np.float64)
    phi = np.asarray(phi, np.float64)
    xc = x.reshape(x.shape[0], -1)
    return (W @ xc).reshape(x.shape), W @ phi


def push_sum_average(graph, x: np.ndarray, iters: int = 200):
    """Run ``iters`` push-sum rounds from ``φ = 1`` and return the
    per-node debiased estimates ``x_i/φ_i`` (each converging to
    ``mean(x)`` on a strongly connected graph) — the reference iteration
    the property tests certify and the bounded-stale engine's
    weighted-mean aggregation approximates one commit at a time."""
    W = push_sum_weights(graph)
    x = np.asarray(x, np.float64)
    phi = np.ones(x.shape[0], np.float64)
    for _ in range(int(iters)):
        x, phi = push_sum_round(W, x, phi)
    return x / phi.reshape((-1,) + (1,) * (x.ndim - 1))


def async_mix_weights(topo: Topology) -> np.ndarray:
    """The (C, C) base mixing-weight matrix for ``sync="bounded_stale"``:
    row ``c`` holds the weight cluster ``c`` gives each peer's freshest
    published delta (support of row c = c's in-neighborhood = the
    staleness-gate set).

    Gather kinds (star/full) model a relay hub that re-broadcasts every
    published delta, so every cluster mixes everyone uniformly (``J/n`` —
    push-sum on the complete graph).  Gossip kinds take the push-sum
    weights of the bidirected graph: ``W[c, p] = 1/(deg(p)+1)`` — each
    peer's out-share of its own delta.  Rows are NOT normalized here:
    ``core.diloco.staleness_weights`` discounts by staleness and
    ``masked_cluster_mean``'s sum-normalization supplies the push-sum
    ``x/φ`` debiasing at commit time.
    """
    n = topo.n
    if topo.kind in GATHER_KINDS:
        return np.full((n, n), 1.0 / n, np.float64)
    return push_sum_weights(topo)


def consensus_distance(stacked: np.ndarray, alive: np.ndarray) -> float:
    """RMS distance of alive rows from their mean — the scalar the timeline
    records as ``disagreement`` (0 for gather, since rows are identical)."""
    alive = np.asarray(alive, bool)
    rows = np.asarray(stacked, np.float64)[alive].reshape(alive.sum(), -1)
    if rows.shape[0] == 0:
        return 0.0
    centred = rows - rows.mean(axis=0, keepdims=True)
    return float(np.sqrt(np.mean(centred ** 2)))


# ---------------------------------------------------------------------------
# mix operators (jax; bitwise-stable unrolled multiply-add chains)
# ---------------------------------------------------------------------------

def mix_row(w_row, parts: Sequence[Any]) -> Any:
    """One cluster's neighborhood average: sum_j w_row[j] * parts[j], as an
    unrolled fp32 multiply-add chain in fixed j order.  ``parts`` must have
    one entry per cluster (zeros for non-neighbors — their weight is 0).
    A proc worker calls this on its own row; ``mix_stacked`` calls it per
    row — identical op sequence, hence bit-identical results."""
    import jax
    import jax.numpy as jnp

    acc = jax.tree.map(lambda x: w_row[0] * x.astype(jnp.float32), parts[0])
    for j in range(1, len(parts)):
        acc = jax.tree.map(lambda a, x: a + w_row[j] * x.astype(jnp.float32),
                           acc, parts[j])
    return acc


def mix_stacked(W, stacked_tree: Any) -> Any:
    """All clusters' neighborhood averages: row c of the result is
    ``mix_row(W[c], rows)``.  W: (C, C), stacked_tree leaves: (C, ...)."""
    import jax
    import jax.numpy as jnp

    from repro.core.diloco import take_row

    n = W.shape[0]
    parts = [take_row(stacked_tree, j) for j in range(n)]
    rows = [mix_row(W[c], parts) for c in range(n)]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *rows)


def mixing_op(topology: Topology, alive: np.ndarray):
    """The ``cluster_mean``-shaped callable for ``core.diloco.diloco_round``
    under this topology and alive mask.

    Gather kinds: masked global mean (unstacked result — the seed repo's
    exact hub path).  Gossip kinds: stacked neighborhood averages through
    the masked MH matrix; the returned op carries ``returns_stacked=True``
    (switches diloco_round to gossip semantics) and ``.matrix`` (the
    ``MixingMatrix`` actually applied, for accounting/inspection).

    NOTE on the jitted backends: this factory closes over a fixed alive
    mask, so it is the API for *eager* callers (tests, notebooks, driving
    ``diloco_round`` directly).  ``sim/simulator.py`` and the proc worker
    instead inline the same primitives (``masked_cluster_mean`` /
    ``mix_stacked`` / ``mix_row``) with the per-round matrix as a traced
    argument — a fresh closure per round would retrace the jit every
    round.  Change the mixing arithmetic in those primitives, not here.
    """
    import jax.numpy as jnp

    from repro.core.membership import masked_cluster_mean

    alive = np.asarray(alive, bool)
    mm = MixingMatrix.metropolis(topology, alive)
    if not topology.is_gossip:
        m = jnp.asarray(alive, jnp.float32)
        op = lambda tree: masked_cluster_mean(tree, m)
        op.returns_stacked = False
    else:
        Wj = jnp.asarray(mm.W)
        op = lambda tree: mix_stacked(Wj, tree)
        op.returns_stacked = True
    op.matrix = mm
    op.topology = topology
    return op
