"""Doubly-stochastic mixing matrices + the gossip mixing operator.

``MixingMatrix`` turns a ``Topology`` into the per-round averaging weights:

 - gather kinds (star/full): ``W = J/n`` — the exact global mean in one
   step (what the hub relay realizes), spectral gap 1;
 - gossip kinds: Metropolis-Hastings weights on the graph,
   ``W_ij = 1/(1 + max(d_i, d_j))`` on edges, self-weight absorbs the rest.
   Symmetric, nonnegative, rows sum to 1 => doubly stochastic, so repeated
   mixing contracts every cluster toward the mean at the rate of the
   spectral gap ``1 - |lambda_2|``.

Membership churn reuses ``core.membership.masked_mixing_matrix`` (row
renormalization: dead rows/cols masked, the self-weight absorbs the lost
mass) so the alive block stays symmetric doubly stochastic.

``mixing_op(topology, alive)`` produces the ``cluster_mean``-shaped callable
``core.diloco.diloco_round`` consumes.  For gather kinds it returns the
masked global mean (bit-identical to the seed's hub path); for gossip kinds
it returns a *stacked* tree — row c is cluster c's neighborhood average —
and is tagged ``returns_stacked=True`` so the round switches to gossip
semantics.

``mix_row``/``mix_stacked`` are deliberately unrolled scalar-weight
multiply-add chains (same trick as ``core.diloco.per_cluster_compress``):
a proc worker computing its own row and the in-process simulator computing
all rows execute the identical op sequence, which is what keeps the two
backends bit-for-bit equal.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional, Sequence

import numpy as np

from repro.topology.graphs import Topology

# jax is imported lazily inside the mix operators: the coordinator and the
# timing-only workers import this module for the numpy-side accounting and
# must not pay (or require) a jax import.


# ---------------------------------------------------------------------------
# matrices (pure numpy)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MixingMatrix:
    """A (n, n) float32 mixing matrix tied to the topology that produced it.
    float32 on purpose: the same bytes feed both simulator backends."""
    W: np.ndarray
    kind: str = "custom"

    def __post_init__(self):
        W = np.asarray(self.W, np.float32)
        if W.ndim != 2 or W.shape[0] != W.shape[1]:
            raise ValueError(f"mixing matrix must be square, got {W.shape}")
        object.__setattr__(self, "W", W)

    @staticmethod
    def metropolis(topo: Topology,
                   alive: Optional[np.ndarray] = None) -> "MixingMatrix":
        """Metropolis-Hastings weights on the (alive-masked) graph.  Gather
        kinds get J/n over the alive set — one hub round IS the global
        mean, not an MH step on the star graph."""
        n = topo.n
        if topo.kind in ("star", "full"):
            W = np.full((n, n), 1.0 / n, np.float64)
        else:
            deg = np.array([topo.degree(c) for c in range(n)], np.float64)
            W = np.zeros((n, n), np.float64)
            for i, j in topo.edges:
                w = 1.0 / (1.0 + max(deg[i], deg[j]))
                W[i, j] = W[j, i] = w
            np.fill_diagonal(W, 1.0 - W.sum(axis=1))
        mm = MixingMatrix(W.astype(np.float32), topo.kind)
        if alive is not None:
            mm = mm.masked(alive)
        return mm

    def masked(self, alive: np.ndarray) -> "MixingMatrix":
        """Membership-masked row renormalization (core.membership)."""
        from repro.core.membership import masked_mixing_matrix

        W = np.asarray(masked_mixing_matrix(self.W, np.asarray(alive)),
                       np.float32)
        return MixingMatrix(W, self.kind)

    def is_doubly_stochastic(self, atol: float = 1e-5) -> bool:
        W = self.W.astype(np.float64)
        return bool((W >= -atol).all()
                    and np.allclose(W.sum(axis=0), 1.0, atol=atol)
                    and np.allclose(W.sum(axis=1), 1.0, atol=atol))

    def spectral_gap(self, alive: Optional[np.ndarray] = None) -> float:
        """1 - |lambda_2| of the (alive block of the) matrix: the per-mix
        contraction rate toward consensus.  Dead identity rows would each
        contribute a spurious eigenvalue 1, hence the restriction."""
        W = self.W.astype(np.float64)
        if alive is not None:
            ids = np.flatnonzero(np.asarray(alive, bool))
            W = W[np.ix_(ids, ids)]
        if W.shape[0] <= 1:
            return 1.0
        eig = np.sort(np.abs(np.linalg.eigvals(W)))[::-1]
        return float(1.0 - eig[1])


def consensus_distance(stacked: np.ndarray, alive: np.ndarray) -> float:
    """RMS distance of alive rows from their mean — the scalar the timeline
    records as ``disagreement`` (0 for gather, since rows are identical)."""
    alive = np.asarray(alive, bool)
    rows = np.asarray(stacked, np.float64)[alive].reshape(alive.sum(), -1)
    if rows.shape[0] == 0:
        return 0.0
    centred = rows - rows.mean(axis=0, keepdims=True)
    return float(np.sqrt(np.mean(centred ** 2)))


# ---------------------------------------------------------------------------
# mix operators (jax; bitwise-stable unrolled multiply-add chains)
# ---------------------------------------------------------------------------

def mix_row(w_row, parts: Sequence[Any]) -> Any:
    """One cluster's neighborhood average: sum_j w_row[j] * parts[j], as an
    unrolled fp32 multiply-add chain in fixed j order.  ``parts`` must have
    one entry per cluster (zeros for non-neighbors — their weight is 0).
    A proc worker calls this on its own row; ``mix_stacked`` calls it per
    row — identical op sequence, hence bit-identical results."""
    import jax
    import jax.numpy as jnp

    acc = jax.tree.map(lambda x: w_row[0] * x.astype(jnp.float32), parts[0])
    for j in range(1, len(parts)):
        acc = jax.tree.map(lambda a, x: a + w_row[j] * x.astype(jnp.float32),
                           acc, parts[j])
    return acc


def mix_stacked(W, stacked_tree: Any) -> Any:
    """All clusters' neighborhood averages: row c of the result is
    ``mix_row(W[c], rows)``.  W: (C, C), stacked_tree leaves: (C, ...)."""
    import jax
    import jax.numpy as jnp

    from repro.core.diloco import take_row

    n = W.shape[0]
    parts = [take_row(stacked_tree, j) for j in range(n)]
    rows = [mix_row(W[c], parts) for c in range(n)]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *rows)


def mixing_op(topology: Topology, alive: np.ndarray):
    """The ``cluster_mean``-shaped callable for ``core.diloco.diloco_round``
    under this topology and alive mask.

    Gather kinds: masked global mean (unstacked result — the seed repo's
    exact hub path).  Gossip kinds: stacked neighborhood averages through
    the masked MH matrix; the returned op carries ``returns_stacked=True``
    (switches diloco_round to gossip semantics) and ``.matrix`` (the
    ``MixingMatrix`` actually applied, for accounting/inspection).

    NOTE on the jitted backends: this factory closes over a fixed alive
    mask, so it is the API for *eager* callers (tests, notebooks, driving
    ``diloco_round`` directly).  ``sim/simulator.py`` and the proc worker
    instead inline the same primitives (``masked_cluster_mean`` /
    ``mix_stacked`` / ``mix_row``) with the per-round matrix as a traced
    argument — a fresh closure per round would retrace the jit every
    round.  Change the mixing arithmetic in those primitives, not here.
    """
    import jax.numpy as jnp

    from repro.core.membership import masked_cluster_mean

    alive = np.asarray(alive, bool)
    mm = MixingMatrix.metropolis(topology, alive)
    if not topology.is_gossip:
        m = jnp.asarray(alive, jnp.float32)
        op = lambda tree: masked_cluster_mean(tree, m)
        op.returns_stacked = False
    else:
        Wj = jnp.asarray(mm.W)
        op = lambda tree: mix_stacked(Wj, tree)
        op.returns_stacked = True
    op.matrix = mm
    op.topology = topology
    return op
