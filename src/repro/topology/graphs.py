"""Communication graphs for the decentralized outer step.

A ``Topology`` is the *shape* of the outer-step communication pattern: which
cluster talks to which.  The seed repo hard-wired a hub (every cluster
reaches a coordinator every round — ``star``); this module makes the graph a
first-class object so the outer sync can also run as neighbor gossip
(NoLoCo-style) over a ring, a 2D torus, or a random k-regular expander.

Two families, with different *semantics* downstream:

 - **gather kinds** (``star``, ``full``): every round realizes the exact
   global average (hub relay / all-gather).  ``star`` is the seed repo's
   coordinator topology; ``full`` is the same average with all-to-all wire
   accounting.  Mixing matrix = J/n (averages in one step, spectral gap 1).
 - **gossip kinds** (``ring``, ``torus``, ``random``): each cluster
   exchanges compressed pseudo-gradients with its graph neighbors only and
   applies a doubly-stochastic local mix (``repro.topology.mixing``).
   Per-cluster outer params are no longer identical after the round;
   information diffuses at the rate of the graph's spectral gap.

Everything here is pure numpy/python — importable by the proc backend's
coordinator without paying a jax import.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

import numpy as np

GATHER_KINDS = ("star", "full")
GOSSIP_KINDS = ("ring", "torus", "random")
KINDS = GATHER_KINDS + GOSSIP_KINDS


@dataclass(frozen=True)
class Topology:
    """An undirected communication graph over ``n`` clusters.

    ``edges`` is a sorted tuple of ``(i, j)`` pairs with ``i < j``.  Use the
    module-level constructors (``ring``/``torus``/``random_regular``/
    ``star``/``full``) or ``make_topology`` rather than building directly.
    """
    kind: str
    n: int
    edges: Tuple[Tuple[int, int], ...]
    meta: Dict[str, int] = field(default_factory=dict, compare=False)

    def __post_init__(self):
        for i, j in self.edges:
            if not (0 <= i < j < self.n):
                raise ValueError(f"bad edge ({i},{j}) for n={self.n}")

    @property
    def is_gossip(self) -> bool:
        return self.kind in GOSSIP_KINDS

    def neighbors(self, c: int) -> Tuple[int, ...]:
        out = [j for i, j in self.edges if i == c]
        out += [i for i, j in self.edges if j == c]
        return tuple(sorted(out))

    def degree(self, c: int) -> int:
        return len(self.neighbors(c))

    def adjacency(self) -> np.ndarray:
        A = np.zeros((self.n, self.n), bool)
        for i, j in self.edges:
            A[i, j] = A[j, i] = True
        return A

    def alive_neighbors(self, c: int, alive: np.ndarray) -> Tuple[int, ...]:
        """Graph neighbors of ``c`` restricted to the alive set."""
        alive = np.asarray(alive, bool)
        return tuple(j for j in self.neighbors(c) if alive[j])

    def is_connected(self, alive: Optional[np.ndarray] = None) -> bool:
        """Connectivity of the (alive-induced) subgraph — gossip only
        contracts to a global consensus on a connected graph."""
        alive = (np.ones(self.n, bool) if alive is None
                 else np.asarray(alive, bool))
        nodes = [int(i) for i in np.flatnonzero(alive)]
        if not nodes:
            return True
        seen, stack = {nodes[0]}, [nodes[0]]
        while stack:
            c = stack.pop()
            for j in self.alive_neighbors(c, alive):
                if j not in seen:
                    seen.add(j)
                    stack.append(j)
        return len(seen) == len(nodes)

    def describe(self) -> str:
        extra = "".join(f" {k}={v}" for k, v in sorted(self.meta.items()))
        return (f"{self.kind}(n={self.n}, |E|={len(self.edges)}{extra})")


@dataclass(frozen=True)
class Digraph:
    """A directed communication graph: arc ``(src, dst)`` means ``src``
    pushes its delta to ``dst``.  This is the asymmetric-uplink setting
    (WAN sites with very different up/down capacity) where doubly-
    stochastic Metropolis-Hastings weights do not exist — push-sum
    (``mixing.push_sum_weights``) mixes correctly with only column
    stochasticity, which any out-degree normalization provides.
    """
    n: int
    arcs: Tuple[Tuple[int, int], ...]

    def __post_init__(self):
        object.__setattr__(
            self, "arcs", tuple(sorted({(int(a), int(b))
                                        for a, b in self.arcs})))
        for a, b in self.arcs:
            if not (0 <= a < self.n and 0 <= b < self.n) or a == b:
                raise ValueError(f"bad arc ({a},{b}) for n={self.n}")

    def out_neighbors(self, c: int) -> Tuple[int, ...]:
        return tuple(sorted(b for a, b in self.arcs if a == c))

    def in_neighbors(self, c: int) -> Tuple[int, ...]:
        return tuple(sorted(a for a, b in self.arcs if b == c))

    def out_degree(self, c: int) -> int:
        return len(self.out_neighbors(c))

    def is_strongly_connected(self) -> bool:
        """Push-sum converges to the true average iff the graph is
        strongly connected (every node's mass can reach every other)."""
        def reach(start, nbrs):
            seen, stack = {start}, [start]
            while stack:
                c = stack.pop()
                for j in nbrs(c):
                    if j not in seen:
                        seen.add(j)
                        stack.append(j)
            return len(seen) == self.n

        return (reach(0, self.out_neighbors) and reach(0, self.in_neighbors))


def directed_ring(n: int) -> Digraph:
    """The canonical asymmetric gossip graph: ``i -> (i+1) % n``."""
    return Digraph(n, tuple((i, (i + 1) % n) for i in range(n)))


def as_digraph(topo: Topology) -> Digraph:
    """Both directions of every undirected edge — how the symmetric
    topologies enter the push-sum weight construction."""
    arcs = []
    for i, j in topo.edges:
        arcs.append((i, j))
        arcs.append((j, i))
    return Digraph(topo.n, tuple(arcs))


def _dedupe(pairs) -> Tuple[Tuple[int, int], ...]:
    return tuple(sorted({(min(a, b), max(a, b)) for a, b in pairs
                         if a != b}))


def ring(n: int) -> Topology:
    return Topology("ring", n, _dedupe((i, (i + 1) % n) for i in range(n)))


def torus(n: int, rows: Optional[int] = None) -> Topology:
    """2D torus on an r x c grid with r*c == n.  ``rows`` defaults to the
    largest divisor of n that is <= sqrt(n) (prime n degenerates to a 1 x n
    wrap — i.e. a ring)."""
    if rows is None:
        rows = max(d for d in range(1, int(math.isqrt(n)) + 1) if n % d == 0)
    if n % rows:
        raise ValueError(f"torus rows={rows} does not divide n={n}")
    cols = n // rows
    idx = lambda r, c: r * cols + c
    pairs = []
    for r in range(rows):
        for c in range(cols):
            pairs.append((idx(r, c), idx(r, (c + 1) % cols)))
            pairs.append((idx(r, c), idx((r + 1) % rows, c)))
    t = Topology("torus", n, _dedupe(pairs))
    t.meta.update(rows=rows, cols=cols)
    return t


def random_regular(n: int, degree: int = 3, seed: int = 0) -> Topology:
    """Random k-regular graph by stub matching (configuration model),
    retried until simple *and* connected.  Deterministic in (n, degree,
    seed) — numpy's PCG64 streams are stable across versions."""
    degree = min(degree, n - 1)
    if degree <= 0:
        raise ValueError("random topology needs degree >= 1 and n >= 2")
    if (n * degree) % 2:
        raise ValueError(f"n*degree must be even (n={n}, degree={degree})")
    rng = np.random.default_rng([seed, n, degree])
    for _ in range(500):
        stubs = np.repeat(np.arange(n), degree)
        rng.shuffle(stubs)
        pairs = stubs.reshape(-1, 2)
        edges = set()
        ok = True
        for a, b in pairs:
            a, b = int(a), int(b)
            e = (min(a, b), max(a, b))
            if a == b or e in edges:
                ok = False
                break
            edges.add(e)
        if not ok:
            continue
        t = Topology("random", n, tuple(sorted(edges)),
                     meta={"degree": degree, "seed": seed})
        if t.is_connected():
            return t
    raise RuntimeError(f"no connected {degree}-regular graph found for "
                       f"n={n} (seed={seed})")


def star(n: int) -> Topology:
    return Topology("star", n, _dedupe((0, i) for i in range(1, n)))


def full(n: int) -> Topology:
    return Topology("full", n, _dedupe((i, j) for i in range(n)
                                       for j in range(i + 1, n)))


def make_topology(kind: str, n: int, *, degree: int = 0,
                  seed: int = 0) -> Topology:
    """Registry constructor — the string surface the Scenario/CLI use.
    ``degree`` is only meaningful for ``random`` (0 = default 3, clamped to
    n-1; bumped by one when n*degree is odd so a matching exists)."""
    if n < 1:
        raise ValueError("need at least one cluster")
    if kind == "ring":
        return ring(n)
    if kind == "torus":
        return torus(n)
    if kind == "random":
        k = degree or min(3, n - 1)
        if (n * k) % 2:
            k = k + 1 if k + 1 <= n - 1 else k - 1
        return random_regular(n, k, seed)
    if kind == "star":
        return star(n)
    if kind == "full":
        return full(n)
    raise ValueError(f"unknown topology kind {kind!r} (choices: {KINDS})")
